//! Bag ⇄ tensor bridge: how a dataflow operator marshals its input bag(s)
//! into the fixed-shape tensors of an AOT artifact and back.
//!
//! Artifacts are compiled for static shapes (see DESIGN.md §7); bags are
//! padded to capacity (with neutral padding values the kernels ignore) and
//! outputs are truncated back. Inputs larger than the artifact capacity are
//! processed in chunks where semantics allow (histogram), otherwise
//! rejected with a clear error so callers fall back to the pure-Rust
//! operator.

use crate::bag::Bag;
use crate::error::{Error, Result};
use crate::value::Value;

/// Which bag⇄tensor bridge an [`XlaCallSpec`] uses.
#[derive(Clone, Debug, PartialEq)]
pub enum BridgeKind {
    /// Input 0: bag of `I64` ids in `[0, bins)`. Output: bag of
    /// `Pair(bin, count)` for non-zero bins. Ids are chunked through the
    /// artifact's `capacity`-sized input; counts accumulate across chunks.
    /// Padding id is `-1` (the kernel counts only ids in range).
    HistogramI64 {
        /// Artifact input length.
        capacity: usize,
        /// Number of count bins (artifact output length).
        bins: usize,
    },
    /// Input 0 (loop-invariant build side): bag of `Pair(src, dst)` edges
    /// over pages `[0, n)`, tensorized ONCE into a dense column-stochastic
    /// transition matrix and kept in operator state across iteration steps
    /// (§7 build-side reuse applied to a tensor operator).
    /// Input 1: bag of `Pair(page, rank)`. Output: bag of `Pair(page,
    /// rank')` after one damped PageRank step.
    PageRankStep {
        /// Number of pages (matrix dimension).
        n: usize,
    },
    /// Input 0: bag of numeric values; the artifact applies an elementwise
    /// function to a `capacity`-length vector. Values are chunked; order is
    /// not preserved (bags are unordered).
    MapF64 {
        /// Artifact input length.
        capacity: usize,
    },
}

/// Full description of an accelerated operator call.
#[derive(Clone, Debug, PartialEq)]
pub struct XlaCallSpec {
    /// Artifact name (file stem in the artifact directory).
    pub artifact: String,
    /// Marshalling strategy.
    pub bridge: BridgeKind,
}

impl XlaCallSpec {
    /// Histogram spec matching `python/compile/kernels/histogram.py`.
    pub fn histogram(capacity: usize, bins: usize) -> XlaCallSpec {
        XlaCallSpec { artifact: "histogram".into(), bridge: BridgeKind::HistogramI64 { capacity, bins } }
    }
    /// PageRank-step spec matching `python/compile/kernels/pagerank.py`.
    pub fn pagerank_step(n: usize) -> XlaCallSpec {
        XlaCallSpec { artifact: "pagerank_step".into(), bridge: BridgeKind::PageRankStep { n } }
    }
    /// Elementwise-increment spec matching `python/compile/kernels/incr.py`.
    pub fn incr(capacity: usize) -> XlaCallSpec {
        XlaCallSpec { artifact: "incr".into(), bridge: BridgeKind::MapF64 { capacity } }
    }

    /// Number of bag inputs this call consumes.
    pub fn arity(&self) -> usize {
        match self.bridge {
            BridgeKind::PageRankStep { .. } => 2,
            _ => 1,
        }
    }
}

/// Tensorized loop-invariant state for [`BridgeKind::PageRankStep`].
pub struct DenseMatrix {
    /// Row-major `n × n` data.
    pub data: Vec<f32>,
    /// Dimension.
    pub n: usize,
}

impl DenseMatrix {
    /// Build the damped column-stochastic PageRank transition matrix from
    /// an edge bag. Dangling pages distribute uniformly.
    pub fn from_edges(edges: &Bag, n: usize) -> Result<DenseMatrix> {
        let mut out_deg = vec![0u32; n];
        let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(edges.len());
        for e in edges {
            let (s, d) = match e {
                Value::Pair(p) => (p.0.as_i64() as usize, p.1.as_i64() as usize),
                other => {
                    return Err(Error::Xla(format!("pagerank edge must be a pair, got {other:?}")))
                }
            };
            if s >= n || d >= n {
                return Err(Error::Xla(format!("edge ({s},{d}) out of range for n={n}")));
            }
            out_deg[s] += 1;
            pairs.push((s, d));
        }
        // M[d][s] = 1/outdeg(s); dangling column s = 1/n.
        let mut data = vec![0f32; n * n];
        for s in 0..n {
            if out_deg[s] == 0 {
                let w = 1.0 / n as f32;
                for d in 0..n {
                    data[d * n + s] = w;
                }
            }
        }
        for (s, d) in pairs {
            data[d * n + s] += 1.0 / out_deg[s] as f32;
        }
        Ok(DenseMatrix { data, n })
    }
}

/// Marshal an i64 bag into padded `capacity`-length i32 chunks
/// (padding = -1, which the histogram kernel ignores).
pub fn ids_to_chunks(bag: &Bag, capacity: usize) -> Result<Vec<Vec<i32>>> {
    let mut chunks = Vec::new();
    let items = bag.items();
    let mut idx = 0;
    while idx < items.len() || (idx == 0 && items.is_empty()) {
        let mut chunk = vec![-1i32; capacity];
        let end = (idx + capacity).min(items.len());
        for (k, v) in items[idx..end].iter().enumerate() {
            chunk[k] = v.as_i64() as i32;
        }
        chunks.push(chunk);
        if items.is_empty() {
            break;
        }
        idx = end;
    }
    Ok(chunks)
}

/// Marshal a rank bag (`Pair(page, rank)`) into a dense f32 vector.
pub fn ranks_to_vec(bag: &Bag, n: usize) -> Result<Vec<f32>> {
    let mut v = vec![0f32; n];
    for e in bag {
        match e {
            Value::Pair(p) => {
                let i = p.0.as_i64() as usize;
                if i >= n {
                    return Err(Error::Xla(format!("rank index {i} out of range n={n}")));
                }
                v[i] = p.1.as_f64() as f32;
            }
            other => return Err(Error::Xla(format!("rank element must be a pair, got {other:?}"))),
        }
    }
    Ok(v)
}

/// Unmarshal a dense f32 vector back into a `Pair(idx, F64)` bag.
pub fn vec_to_ranks(v: &[f32]) -> Vec<Value> {
    v.iter()
        .enumerate()
        .map(|(i, &r)| Value::pair(Value::I64(i as i64), Value::F64(r as f64)))
        .collect()
}

/// Unmarshal histogram counts into `Pair(bin, count)` for non-zero bins.
pub fn counts_to_pairs(counts: &[f32]) -> Vec<Value> {
    counts
        .iter()
        .enumerate()
        .filter(|(_, &c)| c != 0.0)
        .map(|(b, &c)| Value::pair(Value::I64(b as i64), Value::I64(c as i64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matrix_is_column_stochastic() {
        // 0 -> 1, 0 -> 2, 1 -> 0; page 2 dangling.
        let edges = Bag::from_vec(vec![
            Value::pair(Value::I64(0), Value::I64(1)),
            Value::pair(Value::I64(0), Value::I64(2)),
            Value::pair(Value::I64(1), Value::I64(0)),
        ]);
        let m = DenseMatrix::from_edges(&edges, 3).unwrap();
        for s in 0..3 {
            let col_sum: f32 = (0..3).map(|d| m.data[d * 3 + s]).sum();
            assert!((col_sum - 1.0).abs() < 1e-6, "col {s} sums to {col_sum}");
        }
        assert!((m.data[3 + 0] - 0.5).abs() < 1e-6); // M[1][0] = 1/2
    }

    #[test]
    fn edge_out_of_range_rejected() {
        let edges = Bag::from_vec(vec![Value::pair(Value::I64(5), Value::I64(0))]);
        assert!(DenseMatrix::from_edges(&edges, 3).is_err());
    }

    #[test]
    fn ranks_roundtrip() {
        let bag = Bag::from_vec(vec![
            Value::pair(Value::I64(1), Value::F64(0.25)),
            Value::pair(Value::I64(0), Value::F64(0.75)),
        ]);
        let v = ranks_to_vec(&bag, 2).unwrap();
        assert_eq!(v, vec![0.75, 0.25]);
        let back = vec_to_ranks(&v);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0], Value::pair(Value::I64(0), Value::F64(0.75)));
    }

    #[test]
    fn counts_skip_zero_bins() {
        let pairs = counts_to_pairs(&[0.0, 2.0, 0.0, 1.0]);
        assert_eq!(
            pairs,
            vec![
                Value::pair(Value::I64(1), Value::I64(2)),
                Value::pair(Value::I64(3), Value::I64(1)),
            ]
        );
    }

    #[test]
    fn spec_arity() {
        assert_eq!(XlaCallSpec::histogram(8, 4).arity(), 1);
        assert_eq!(XlaCallSpec::pagerank_step(16).arity(), 2);
    }

    #[test]
    fn ids_chunking_pads_with_minus_one() {
        let bag = Bag::from_vec((0..5).map(Value::I64).collect());
        let chunks = ids_to_chunks(&bag, 4).unwrap();
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0], vec![0, 1, 2, 3]);
        assert_eq!(chunks[1], vec![4, -1, -1, -1]);
    }

    #[test]
    fn empty_bag_yields_one_padded_chunk() {
        let chunks = ids_to_chunks(&Bag::new(), 3).unwrap();
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0], vec![-1, -1, -1]);
    }
}
