//! The XLA service thread. The `xla` crate's PJRT handles are neither
//! `Send` nor `Sync` (they wrap `Rc` + raw pointers), so all PJRT state —
//! client, compiled executables, cached device literals — lives on ONE
//! dedicated thread, and dataflow operators talk to it through channels.
//! This mirrors a real deployment where the accelerator is driven by a
//! single runtime thread per device.

use crate::error::{Error, Result};
// The in-repo PJRT API stand-in (the real `xla` crate is unavailable
// offline); every `xla::` path below resolves against it.
use crate::runtime::xla;
use once_cell::sync::OnceCell;
use rustc_hash::FxHashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

/// Tensor payload crossing the channel (host data; `Send`).
#[derive(Clone, Debug)]
pub enum TensorData {
    /// f32 data.
    F32(Vec<f32>),
    /// i32 data.
    I32(Vec<i32>),
}

/// One executable operand.
#[derive(Clone, Debug)]
pub enum Operand {
    /// Send the tensor inline.
    Inline {
        /// Data.
        data: TensorData,
        /// Dimensions.
        dims: Vec<i64>,
    },
    /// Use a literal previously cached on the service thread (loop-
    /// invariant operands, e.g. the PageRank transition matrix — §7 state
    /// reuse across the channel boundary).
    Cached {
        /// Cache key.
        key: u64,
    },
    /// Cache the tensor under `key`, then use it.
    CacheAndUse {
        /// Cache key.
        key: u64,
        /// Data.
        data: TensorData,
        /// Dimensions.
        dims: Vec<i64>,
    },
}

enum Request {
    Execute {
        artifact: String,
        operands: Vec<Operand>,
        reply: Sender<Result<Vec<f32>>>,
    },
    DropCached {
        key: u64,
    },
    /// Is the artifact file present (without compiling)?
    Probe {
        artifact: String,
        reply: Sender<bool>,
    },
}

/// Handle to the service thread.
pub struct XlaService {
    tx: Mutex<Sender<Request>>,
}

impl XlaService {
    /// The process-global service (artifact dir: `$LABY_ARTIFACT_DIR` or
    /// `artifacts/`, resolved on the service thread at startup).
    pub fn global() -> &'static XlaService {
        static SVC: OnceCell<XlaService> = OnceCell::new();
        SVC.get_or_init(|| {
            let dir = std::env::var("LABY_ARTIFACT_DIR").unwrap_or_else(|_| "artifacts".into());
            XlaService::spawn(dir)
        })
    }

    /// Spawn a service thread over an artifact directory.
    pub fn spawn(dir: String) -> XlaService {
        let (tx, rx) = channel::<Request>();
        std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || service_main(dir, rx))
            .expect("spawn xla service");
        XlaService { tx: Mutex::new(tx) }
    }

    /// Execute an artifact; blocks for the reply.
    pub fn execute(&self, artifact: &str, operands: Vec<Operand>) -> Result<Vec<f32>> {
        let (rtx, rrx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Execute { artifact: artifact.to_string(), operands, reply: rtx })
            .map_err(|_| Error::Xla("xla service thread gone".into()))?;
        rrx.recv().map_err(|_| Error::Xla("xla service dropped reply".into()))?
    }

    /// Drop a cached literal.
    pub fn drop_cached(&self, key: u64) {
        let _ = self.tx.lock().unwrap().send(Request::DropCached { key });
    }

    /// Check that an artifact file exists.
    pub fn available(&self, artifact: &str) -> bool {
        let (rtx, rrx) = channel();
        if self
            .tx
            .lock()
            .unwrap()
            .send(Request::Probe { artifact: artifact.to_string(), reply: rtx })
            .is_err()
        {
            return false;
        }
        rrx.recv().unwrap_or(false)
    }
}

/// Allocate a process-unique cache key.
pub fn fresh_cache_key() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

// ---- service thread internals (PJRT objects never leave this fn) -------

fn make_literal(data: &TensorData, dims: &[i64]) -> Result<xla::Literal> {
    let lit = match data {
        TensorData::F32(v) => xla::Literal::vec1(v),
        TensorData::I32(v) => xla::Literal::vec1(v),
    };
    lit.reshape(dims).map_err(|e| Error::Xla(format!("reshape{dims:?}: {e:?}")))
}

fn service_main(dir: String, rx: std::sync::mpsc::Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Answer every request with the construction error.
            while let Ok(req) = rx.recv() {
                if let Request::Execute { reply, .. } = req {
                    let _ = reply.send(Err(Error::Xla(format!("PjRtClient::cpu: {e:?}"))));
                }
            }
            return;
        }
    };
    let mut executables: FxHashMap<String, xla::PjRtLoadedExecutable> = FxHashMap::default();
    let mut cache: FxHashMap<u64, xla::Literal> = FxHashMap::default();
    let path_of = |name: &str| format!("{dir}/{name}.hlo.txt");

    while let Ok(req) = rx.recv() {
        match req {
            Request::Probe { artifact, reply } => {
                let _ = reply.send(std::path::Path::new(&path_of(&artifact)).exists());
            }
            Request::DropCached { key } => {
                cache.remove(&key);
            }
            Request::Execute { artifact, operands, reply } => {
                let result = (|| -> Result<Vec<f32>> {
                    if !executables.contains_key(&artifact) {
                        let path = path_of(&artifact);
                        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                            Error::Xla(format!(
                                "load {path}: {e:?} (run `make artifacts` first)"
                            ))
                        })?;
                        let comp = xla::XlaComputation::from_proto(&proto);
                        let exe = client
                            .compile(&comp)
                            .map_err(|e| Error::Xla(format!("compile {artifact}: {e:?}")))?;
                        executables.insert(artifact.clone(), exe);
                    }
                    let exe = executables.get(&artifact).unwrap();
                    let mut lits: Vec<xla::Literal> = Vec::with_capacity(operands.len());
                    for op in &operands {
                        match op {
                            Operand::Inline { data, dims } => lits.push(make_literal(data, dims)?),
                            Operand::Cached { key } => {
                                let lit = cache.get(key).ok_or_else(|| {
                                    Error::Xla(format!("cache key {key} missing"))
                                })?;
                                // Literal is not Clone-cheap; re-register by
                                // copying the backing data via reshape(id).
                                let shape = lit
                                    .array_shape()
                                    .map_err(|e| Error::Xla(format!("shape: {e:?}")))?;
                                let dims: Vec<i64> = shape.dims().to_vec();
                                lits.push(
                                    lit.reshape(&dims)
                                        .map_err(|e| Error::Xla(format!("copy: {e:?}")))?,
                                );
                            }
                            Operand::CacheAndUse { key, data, dims } => {
                                let lit = make_literal(data, dims)?;
                                let lit2 = lit
                                    .reshape(dims)
                                    .map_err(|e| Error::Xla(format!("copy: {e:?}")))?;
                                cache.insert(*key, lit);
                                lits.push(lit2);
                            }
                        }
                    }
                    let bufs = exe
                        .execute::<xla::Literal>(&lits)
                        .map_err(|e| Error::Xla(format!("execute {artifact}: {e:?}")))?;
                    let lit = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| Error::Xla(format!("fetch {artifact}: {e:?}")))?;
                    // aot.py lowers with return_tuple=True.
                    let out = lit
                        .to_tuple1()
                        .map_err(|e| Error::Xla(format!("tuple {artifact}: {e:?}")))?;
                    out.to_vec::<f32>()
                        .map_err(|e| Error::Xla(format!("to_vec {artifact}: {e:?}")))
                })();
                let _ = reply.send(result);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_yields_clean_error() {
        let svc = XlaService::spawn("/nonexistent-artifacts".into());
        assert!(!svc.available("nope"));
        let err = svc
            .execute("nope", vec![Operand::Inline { data: TensorData::F32(vec![1.0]), dims: vec![1] }])
            .unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn cache_keys_are_unique() {
        let a = fresh_cache_key();
        let b = fresh_cache_key();
        assert_ne!(a, b);
    }
}
