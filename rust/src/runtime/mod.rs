//! PJRT runtime: load AOT-compiled XLA artifacts (HLO **text**, produced
//! once by `python/compile/aot.py` from JAX + Pallas kernels) and execute
//! them from dataflow operators. Python never runs on this path.
//!
//! Interchange is HLO text, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! All PJRT objects live on a dedicated [`service::XlaService`] thread
//! (the crate's handles are not `Send`); operators marshal host tensors
//! over channels, with loop-invariant operands cached device-side.
//!
//! The PJRT bindings themselves are provided by the in-repo [`xla`]
//! module — an offline API stand-in for the real `xla` crate (which the
//! build environment cannot fetch). Artifact probing and diagnostics
//! work; actual device execution reports the backend as not linked, and
//! the artifact-gated tests skip accordingly.

pub mod bridge;
pub mod service;
pub mod xla;

pub use bridge::{BridgeKind, XlaCallSpec};
pub use service::{Operand, TensorData, XlaService};
