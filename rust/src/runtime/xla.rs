//! Offline stand-in for the `xla` (PJRT bindings) crate, which is not
//! available in this build environment (the registry has no `xla`
//! package; see DESIGN.md §2). It implements exactly the API surface
//! [`super::service`] uses, with the same shapes and `Debug`-printable
//! errors, so the service thread compiles and degrades gracefully:
//!
//! * probing artifacts still answers from the filesystem, so the
//!   artifact-gated tests skip cleanly;
//! * loading a *missing* artifact file reports the same "run `make
//!   artifacts`" hint as the real path;
//! * actually compiling/executing an artifact reports that the PJRT
//!   backend is not linked.
//!
//! Swapping the real crate back in is a one-line change in
//! `runtime/mod.rs` (drop this module and add the dependency) — the call
//! sites in `service.rs` are untouched.

/// Error type standing in for the real crate's error enum (only ever
/// observed through `{:?}` formatting in `service.rs`).
#[derive(Debug, Clone)]
pub struct XlaStubError(pub String);

/// Host literal (tensor) handle.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from host data.
    pub fn vec1<T: Copy>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64] }
    }

    /// Reshape (also used as a copy in the service).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaStubError> {
        Ok(Literal { dims: dims.to_vec() })
    }

    /// Array shape of the literal.
    pub fn array_shape(&self) -> Result<ArrayShape, XlaStubError> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Unwrap a 1-tuple result.
    pub fn to_tuple1(&self) -> Result<Literal, XlaStubError> {
        Err(XlaStubError("PJRT backend not linked (xla stub)".into()))
    }

    /// Copy out host data.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaStubError> {
        Err(XlaStubError("PJRT backend not linked (xla stub)".into()))
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Load an HLO text file; missing files error (matching the real
    /// path's "run `make artifacts`" diagnostic in `service.rs`).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaStubError> {
        if std::path::Path::new(path).exists() {
            Ok(HloModuleProto)
        } else {
            Err(XlaStubError(format!("no such file: {path}")))
        }
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaStubError> {
        Err(XlaStubError("PJRT backend not linked (xla stub)".into()))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with literal arguments.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaStubError> {
        Err(XlaStubError("PJRT backend not linked (xla stub)".into()))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Construct the CPU client. The stub always succeeds so artifact
    /// probing and the missing-file diagnostics keep working; failures
    /// surface at compile/execute time instead.
    pub fn cpu() -> Result<PjRtClient, XlaStubError> {
        Ok(PjRtClient)
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaStubError> {
        Err(XlaStubError("PJRT backend not linked (xla stub)".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_file_errors_with_path() {
        let err = HloModuleProto::from_text_file("/definitely/not/there.hlo.txt").unwrap_err();
        assert!(format!("{err:?}").contains("not/there"));
    }

    #[test]
    fn literal_round_trips_shape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        let r = l.reshape(&[3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[3]);
        assert!(l.to_vec::<f32>().is_err(), "stub cannot materialize data");
    }
}
