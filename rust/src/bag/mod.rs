//! The `Bag` parallel-collection abstraction (§2.3 of the paper).
//!
//! A bag is an unordered multiset of [`Value`]s. During distributed
//! execution bags only exist as *partitions* streaming through operator
//! instances; this materialized form is used by sources, sinks, the
//! single-threaded baseline, tests, and the tensor bridge.

pub mod column;

pub use column::ColumnBatch;

use crate::value::Value;
use rustc_hash::FxHashMap;

/// A materialized multiset of values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Bag {
    items: Vec<Value>,
}

impl Bag {
    /// An empty bag.
    pub fn new() -> Bag {
        Bag { items: Vec::new() }
    }

    /// Build a bag from items.
    pub fn from_vec(items: Vec<Value>) -> Bag {
        Bag { items }
    }

    /// A one-element bag — the lifted form of a scalar (§5.2).
    pub fn singleton(v: Value) -> Bag {
        Bag { items: vec![v] }
    }

    /// Number of elements (with multiplicity).
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True if the bag holds no elements.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Append an element.
    pub fn push(&mut self, v: Value) {
        self.items.push(v);
    }

    /// Borrow the backing items (unspecified order).
    pub fn items(&self) -> &[Value] {
        &self.items
    }

    /// Consume into the backing items (unspecified order).
    pub fn into_items(self) -> Vec<Value> {
        self.items
    }

    /// The single element of a singleton bag (lifted scalar).
    ///
    /// Errors if the bag does not contain exactly one element — a lifted
    /// scalar must always be a one-element bag.
    pub fn expect_singleton(&self) -> crate::Result<&Value> {
        if self.items.len() == 1 {
            Ok(&self.items[0])
        } else {
            Err(crate::Error::exec(format!(
                "expected singleton bag, got {} elements",
                self.items.len()
            )))
        }
    }

    /// Multiset equality: same elements with same multiplicities,
    /// irrespective of internal order. This is the correctness notion used
    /// by every cross-executor equivalence test.
    pub fn multiset_eq(&self, other: &Bag) -> bool {
        if self.items.len() != other.items.len() {
            return false;
        }
        let mut counts: FxHashMap<&Value, i64> = FxHashMap::default();
        for v in &self.items {
            *counts.entry(v).or_insert(0) += 1;
        }
        for v in &other.items {
            match counts.get_mut(v) {
                Some(c) => *c -= 1,
                None => return false,
            }
        }
        counts.values().all(|&c| c == 0)
    }

    /// A canonically sorted copy (for diffing / display in tests).
    pub fn sorted(&self) -> Vec<Value> {
        let mut v = self.items.clone();
        v.sort();
        v
    }
}

/// A delta against a bag: elements added, plus — where the operator
/// algebra supports them (keyed upserts, where a changed key's new rows
/// supersede its old ones) — elements retracted.
///
/// This is the materialized form of what the delta-incremental
/// iteration engine circulates per superstep: on the wire only the
/// additions travel (a changed key *implies* retraction of its previous
/// rows at the consumer's indexed store, see `ops::state`), but tests
/// and baselines use the explicit form to state and check the algebra.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Delta {
    /// Elements added (with multiplicity).
    pub adds: Vec<Value>,
    /// Elements retracted (with multiplicity); must be present in the
    /// bag the delta is applied to.
    pub retracts: Vec<Value>,
}

impl Delta {
    /// A pure-additions delta (the frontier/semi-naive case).
    pub fn additions(adds: Vec<Value>) -> Delta {
        Delta { adds, retracts: Vec::new() }
    }

    /// True when applying the delta would not change any bag.
    pub fn is_empty(&self) -> bool {
        self.adds.is_empty() && self.retracts.is_empty()
    }

    /// Number of changed rows the delta carries.
    pub fn len(&self) -> usize {
        self.adds.len() + self.retracts.len()
    }

    /// Apply to a materialized bag: remove one occurrence per
    /// retraction, then append the additions. Multiset semantics —
    /// internal order is unspecified.
    pub fn apply_to(&self, bag: &mut Bag) {
        if !self.retracts.is_empty() {
            let mut dec: FxHashMap<&Value, usize> = FxHashMap::default();
            for r in &self.retracts {
                *dec.entry(r).or_insert(0) += 1;
            }
            let mut kept = Vec::with_capacity(bag.items.len());
            for v in bag.items.drain(..) {
                match dec.get_mut(&v) {
                    Some(c) if *c > 0 => *c -= 1,
                    _ => kept.push(v),
                }
            }
            bag.items = kept;
        }
        bag.items.extend(self.adds.iter().cloned());
    }
}

impl FromIterator<Value> for Bag {
    fn from_iter<T: IntoIterator<Item = Value>>(iter: T) -> Self {
        Bag { items: iter.into_iter().collect() }
    }
}

impl IntoIterator for Bag {
    type Item = Value;
    type IntoIter = std::vec::IntoIter<Value>;
    fn into_iter(self) -> Self::IntoIter {
        self.items.into_iter()
    }
}

impl<'a> IntoIterator for &'a Bag {
    type Item = &'a Value;
    type IntoIter = std::slice::Iter<'a, Value>;
    fn into_iter(self) -> Self::IntoIter {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiset_eq_ignores_order() {
        let a = Bag::from_vec(vec![Value::I64(1), Value::I64(2), Value::I64(2)]);
        let b = Bag::from_vec(vec![Value::I64(2), Value::I64(1), Value::I64(2)]);
        assert!(a.multiset_eq(&b));
    }

    #[test]
    fn multiset_eq_respects_multiplicity() {
        let a = Bag::from_vec(vec![Value::I64(1), Value::I64(2)]);
        let b = Bag::from_vec(vec![Value::I64(1), Value::I64(1)]);
        assert!(!a.multiset_eq(&b));
        let c = Bag::from_vec(vec![Value::I64(1)]);
        assert!(!a.multiset_eq(&c));
    }

    #[test]
    fn singleton_roundtrip() {
        let b = Bag::singleton(Value::I64(9));
        assert_eq!(b.expect_singleton().unwrap(), &Value::I64(9));
        assert!(Bag::new().expect_singleton().is_err());
        assert!(Bag::from_vec(vec![Value::I64(1), Value::I64(2)])
            .expect_singleton()
            .is_err());
    }

    #[test]
    fn delta_applies_retractions_then_additions() {
        let mut b = Bag::from_vec(vec![Value::I64(1), Value::I64(1), Value::I64(2)]);
        let d = Delta { adds: vec![Value::I64(3)], retracts: vec![Value::I64(1)] };
        d.apply_to(&mut b);
        // One occurrence of 1 retracted, the other kept; 3 added.
        assert!(b.multiset_eq(&Bag::from_vec(vec![
            Value::I64(1),
            Value::I64(2),
            Value::I64(3)
        ])));
        assert!(!d.is_empty());
        assert_eq!(d.len(), 2);
        assert!(Delta::additions(Vec::new()).is_empty());
    }

    #[test]
    fn from_iterator_collects() {
        let b: Bag = (0..5).map(Value::I64).collect();
        assert_eq!(b.len(), 5);
    }
}
