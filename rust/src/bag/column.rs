//! SoA (structure-of-arrays) column batches for the typed data plane.
//!
//! The batched engine moves `&[Value]` slices between operators; every
//! hot kernel pays one enum dispatch (and often an `Arc` clone) per
//! element. When `opt::types` proves an edge's element type, kernels
//! decode the arriving slice ONCE into a [`ColumnBatch`] — flat machine
//! vectors — run their monomorphic loops over raw `i64`/`f64` lanes, and
//! encode back to `Value`s only at the operator boundary.
//!
//! The decode is *verified*: [`ColumnBatch::from_values`] checks every
//! element against the expected layout and returns `None` on the first
//! mismatch, so an optimistic inference result degrades to the dynamic
//! path instead of corrupting data. The `Dyn` variant wraps a dynamic
//! buffer without copying, which is what makes the typed/dynamic
//! boundary free when inference gave up (`docs/columnar.md`).

use crate::value::{f64_key_hash, i64_key_hash, ElemType, Value};

/// One decoded batch in SoA layout. Key/value pair shapes keep two
/// parallel columns so keyed kernels (`reduceByKey`, join probes, hash
/// scatter) read keys without touching payloads.
#[derive(Clone, Debug, PartialEq)]
pub enum ColumnBatch {
    /// `i64` scalars.
    I64(Vec<i64>),
    /// `f64` scalars.
    F64(Vec<f64>),
    /// Booleans.
    Bool(Vec<bool>),
    /// `pair(i64, i64)` elements as parallel key/value columns.
    PairII {
        /// Keys (first pair component).
        k: Vec<i64>,
        /// Values (second pair component).
        v: Vec<i64>,
    },
    /// `pair(i64, f64)` elements as parallel key/value columns.
    PairIF {
        /// Keys (first pair component).
        k: Vec<i64>,
        /// Values (second pair component).
        v: Vec<f64>,
    },
    /// Fallback: the dynamic representation, wrapped without copying.
    Dyn(Vec<Value>),
}

impl ColumnBatch {
    /// Does `t` have a dedicated SoA layout (anything else rides the
    /// `Dyn` fallback)?
    pub fn supports(t: &ElemType) -> bool {
        match t {
            ElemType::I64 | ElemType::F64 | ElemType::Bool => true,
            ElemType::Pair(k, v) => {
                matches!(
                    (k.as_ref(), v.as_ref()),
                    (ElemType::I64, ElemType::I64) | (ElemType::I64, ElemType::F64)
                )
            }
            _ => false,
        }
    }

    /// An empty batch with the layout of `t` (`Dyn` layout when `t` has
    /// no SoA representation).
    pub fn empty_for(t: &ElemType) -> ColumnBatch {
        match t {
            ElemType::I64 => ColumnBatch::I64(Vec::new()),
            ElemType::F64 => ColumnBatch::F64(Vec::new()),
            ElemType::Bool => ColumnBatch::Bool(Vec::new()),
            ElemType::Pair(k, v) => match (k.as_ref(), v.as_ref()) {
                (ElemType::I64, ElemType::I64) => {
                    ColumnBatch::PairII { k: Vec::new(), v: Vec::new() }
                }
                (ElemType::I64, ElemType::F64) => {
                    ColumnBatch::PairIF { k: Vec::new(), v: Vec::new() }
                }
                _ => ColumnBatch::Dyn(Vec::new()),
            },
            _ => ColumnBatch::Dyn(Vec::new()),
        }
    }

    /// Verified decode: every element of `vs` must match the layout of
    /// `want`, otherwise `None` (the caller keeps the dynamic path; no
    /// partial state escapes). `want = Dyn` clones into the `Dyn`
    /// wrapper — callers on the hot path avoid that by not decoding at
    /// all when inference gave up.
    pub fn from_values(vs: &[Value], want: &ElemType) -> Option<ColumnBatch> {
        match want {
            ElemType::I64 => {
                let mut col = Vec::with_capacity(vs.len());
                for v in vs {
                    match v {
                        Value::I64(x) => col.push(*x),
                        _ => return None,
                    }
                }
                Some(ColumnBatch::I64(col))
            }
            ElemType::F64 => {
                let mut col = Vec::with_capacity(vs.len());
                for v in vs {
                    match v {
                        Value::F64(x) => col.push(*x),
                        _ => return None,
                    }
                }
                Some(ColumnBatch::F64(col))
            }
            ElemType::Bool => {
                let mut col = Vec::with_capacity(vs.len());
                for v in vs {
                    match v {
                        Value::Bool(x) => col.push(*x),
                        _ => return None,
                    }
                }
                Some(ColumnBatch::Bool(col))
            }
            ElemType::Pair(kt, vt) => match (kt.as_ref(), vt.as_ref()) {
                (ElemType::I64, ElemType::I64) => {
                    let mut k = Vec::with_capacity(vs.len());
                    let mut pv = Vec::with_capacity(vs.len());
                    for v in vs {
                        match v {
                            Value::Pair(p) => match (&p.0, &p.1) {
                                (Value::I64(a), Value::I64(b)) => {
                                    k.push(*a);
                                    pv.push(*b);
                                }
                                _ => return None,
                            },
                            _ => return None,
                        }
                    }
                    Some(ColumnBatch::PairII { k, v: pv })
                }
                (ElemType::I64, ElemType::F64) => {
                    let mut k = Vec::with_capacity(vs.len());
                    let mut pv = Vec::with_capacity(vs.len());
                    for v in vs {
                        match v {
                            Value::Pair(p) => match (&p.0, &p.1) {
                                (Value::I64(a), Value::F64(b)) => {
                                    k.push(*a);
                                    pv.push(*b);
                                }
                                _ => return None,
                            },
                            _ => return None,
                        }
                    }
                    Some(ColumnBatch::PairIF { k, v: pv })
                }
                _ => Some(ColumnBatch::Dyn(vs.to_vec())),
            },
            _ => Some(ColumnBatch::Dyn(vs.to_vec())),
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            ColumnBatch::I64(c) => c.len(),
            ColumnBatch::F64(c) => c.len(),
            ColumnBatch::Bool(c) => c.len(),
            ColumnBatch::PairII { k, .. } => k.len(),
            ColumnBatch::PairIF { k, .. } => k.len(),
            ColumnBatch::Dyn(c) => c.len(),
        }
    }

    /// True when the batch holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Compact the batch to only the rows `mask` selects, in place and
    /// order-preserving. This is the single data movement of a masked
    /// filter→map chain: interior typed filters only clear mask bits
    /// ([`crate::opt::types::TypedUdf1::filter_mask`]) and interior maps
    /// skip dead lanes, so survivors are moved exactly once — here, at
    /// chain emission — instead of once per filter stage.
    ///
    /// `mask.len()` must equal `self.len()`.
    pub fn compact(&mut self, mask: &[bool]) {
        debug_assert_eq!(mask.len(), self.len(), "mask is row-parallel");
        fn keep<T>(col: &mut Vec<T>, mask: &[bool]) {
            let mut r = 0;
            col.retain(|_| {
                let k = mask[r];
                r += 1;
                k
            });
        }
        match self {
            ColumnBatch::I64(c) => keep(c, mask),
            ColumnBatch::F64(c) => keep(c, mask),
            ColumnBatch::Bool(c) => keep(c, mask),
            ColumnBatch::PairII { k, v } => {
                keep(k, mask);
                keep(v, mask);
            }
            ColumnBatch::PairIF { k, v } => {
                keep(k, mask);
                keep(v, mask);
            }
            ColumnBatch::Dyn(c) => keep(c, mask),
        }
    }

    /// Encode back to the dynamic representation, appending to `out`
    /// (consumes the batch; the `Dyn` variant moves without re-allocating
    /// when `out` is empty).
    pub fn append_to_values(self, out: &mut Vec<Value>) {
        match self {
            ColumnBatch::I64(c) => out.extend(c.into_iter().map(Value::I64)),
            ColumnBatch::F64(c) => out.extend(c.into_iter().map(Value::F64)),
            ColumnBatch::Bool(c) => out.extend(c.into_iter().map(Value::Bool)),
            ColumnBatch::PairII { k, v } => out.extend(
                k.into_iter().zip(v).map(|(a, b)| Value::pair(Value::I64(a), Value::I64(b))),
            ),
            ColumnBatch::PairIF { k, v } => out.extend(
                k.into_iter().zip(v).map(|(a, b)| Value::pair(Value::I64(a), Value::F64(b))),
            ),
            ColumnBatch::Dyn(mut c) => {
                if out.is_empty() {
                    // Zero-copy at the typed/dynamic boundary.
                    std::mem::swap(out, &mut c);
                } else {
                    out.append(&mut c);
                }
            }
        }
    }

    /// Encode to a fresh dynamic vector (consumes the batch).
    pub fn into_values(self) -> Vec<Value> {
        let mut out = Vec::new();
        self.append_to_values(&mut out);
        out
    }

    /// Append the partitioning-key hash of every element to `out`, in
    /// element order — bit-identical to [`Value::key_hash`] on the
    /// encoded form, so the engine's scatter can route whole columns
    /// through its existing shared hash buffer.
    pub fn key_hashes_into(&self, out: &mut Vec<u64>) {
        match self {
            ColumnBatch::I64(c) => out.extend(c.iter().map(|&x| i64_key_hash(x))),
            ColumnBatch::F64(c) => out.extend(c.iter().map(|&x| f64_key_hash(x))),
            ColumnBatch::Bool(c) => {
                out.extend(c.iter().map(|&b| Value::Bool(b).key_hash()))
            }
            ColumnBatch::PairII { k, .. } | ColumnBatch::PairIF { k, .. } => {
                out.extend(k.iter().map(|&x| i64_key_hash(x)))
            }
            ColumnBatch::Dyn(c) => out.extend(c.iter().map(Value::key_hash)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ii(k: i64, v: i64) -> Value {
        Value::pair(Value::I64(k), Value::I64(v))
    }

    #[test]
    fn verified_decode_roundtrips() {
        let vs: Vec<Value> = (0..5).map(Value::I64).collect();
        let col = ColumnBatch::from_values(&vs, &ElemType::I64).unwrap();
        assert_eq!(col, ColumnBatch::I64(vec![0, 1, 2, 3, 4]));
        assert_eq!(col.len(), 5);
        assert_eq!(col.into_values(), vs);

        let pairs: Vec<Value> = (0..3).map(|x| ii(x % 2, x)).collect();
        let t = ElemType::Pair(Box::new(ElemType::I64), Box::new(ElemType::I64));
        let col = ColumnBatch::from_values(&pairs, &t).unwrap();
        assert_eq!(col.into_values(), pairs);

        let fs = vec![Value::F64(1.5), Value::F64(f64::NAN)];
        let col = ColumnBatch::from_values(&fs, &ElemType::F64).unwrap();
        assert_eq!(col.len(), 2);
        // NaN round-trips through the column (total-order equality).
        assert_eq!(col.into_values(), fs);
    }

    #[test]
    fn decode_rejects_shape_mismatch() {
        let vs = vec![Value::I64(1), Value::F64(2.0)];
        assert!(ColumnBatch::from_values(&vs, &ElemType::I64).is_none());
        let t = ElemType::Pair(Box::new(ElemType::I64), Box::new(ElemType::I64));
        assert!(ColumnBatch::from_values(&[ii(1, 2), Value::I64(3)], &t).is_none());
        assert!(ColumnBatch::from_values(
            &[Value::pair(Value::I64(1), Value::F64(0.5))],
            &t
        )
        .is_none());
    }

    #[test]
    fn unsupported_types_fall_back_to_dyn() {
        assert!(!ColumnBatch::supports(&ElemType::Str));
        assert!(!ColumnBatch::supports(&ElemType::Dyn));
        assert!(ColumnBatch::supports(&ElemType::Pair(
            Box::new(ElemType::I64),
            Box::new(ElemType::F64)
        )));
        let vs = vec![Value::str("a"), Value::str("b")];
        let col = ColumnBatch::from_values(&vs, &ElemType::Str).unwrap();
        assert!(matches!(col, ColumnBatch::Dyn(_)));
        assert_eq!(col.into_values(), vs);
        assert!(ColumnBatch::empty_for(&ElemType::I64).is_empty());
    }

    #[test]
    fn compact_with_mask_keeps_parallel_columns_aligned() {
        let pairs: Vec<Value> = (0..6).map(|x| ii(x, x * 10)).collect();
        let t = ElemType::Pair(Box::new(ElemType::I64), Box::new(ElemType::I64));
        let mut col = ColumnBatch::from_values(&pairs, &t).unwrap();
        col.compact(&[true, false, true, false, false, true]);
        assert_eq!(col.into_values(), vec![ii(0, 0), ii(2, 20), ii(5, 50)]);

        let mut scalars = ColumnBatch::from_values(
            &(0..4).map(Value::I64).collect::<Vec<_>>(),
            &ElemType::I64,
        )
        .unwrap();
        scalars.compact(&[false, true, true, false]);
        assert_eq!(scalars, ColumnBatch::I64(vec![1, 2]));

        // All-true is a no-op; all-false empties the batch.
        let mut b = ColumnBatch::Bool(vec![true, false]);
        b.compact(&[true, true]);
        assert_eq!(b.len(), 2);
        b.compact(&[false, false]);
        assert!(b.is_empty());

        let mut d = ColumnBatch::Dyn(vec![Value::str("a"), Value::str("b")]);
        d.compact(&[false, true]);
        assert_eq!(d.into_values(), vec![Value::str("b")]);
    }

    #[test]
    fn key_hashes_match_dynamic_key_hash() {
        let pairs: Vec<Value> = (0..7).map(|x| ii(x % 3, x * 10)).collect();
        let t = ElemType::Pair(Box::new(ElemType::I64), Box::new(ElemType::I64));
        let col = ColumnBatch::from_values(&pairs, &t).unwrap();
        let mut got = Vec::new();
        col.key_hashes_into(&mut got);
        let want: Vec<u64> = pairs.iter().map(Value::key_hash).collect();
        assert_eq!(got, want);

        let scalars: Vec<Value> = (-3..3).map(Value::I64).collect();
        let col = ColumnBatch::from_values(&scalars, &ElemType::I64).unwrap();
        let mut got = Vec::new();
        col.key_hashes_into(&mut got);
        assert_eq!(got, scalars.iter().map(Value::key_hash).collect::<Vec<_>>());

        let bools = vec![Value::Bool(true), Value::Bool(false)];
        let col = ColumnBatch::from_values(&bools, &ElemType::Bool).unwrap();
        let mut got = Vec::new();
        col.key_hashes_into(&mut got);
        assert_eq!(got, bools.iter().map(Value::key_hash).collect::<Vec<_>>());
    }
}
