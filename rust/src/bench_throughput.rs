//! `bench-throughput` — data-plane throughput in **elements per second**
//! for the hot operator kernels (map, fused map/filter chain, hash-join
//! probe, reduceByKey) at worker counts {1, 2, 4}, plus a before/after
//! series pitting the batched `Transformation` interface against the
//! legacy element-at-a-time path (`ExecConfig::element_path`).
//!
//! Programs are built with the Rust builder frontend (native-closure
//! UDFs), so the numbers measure the data plane — per-element dispatch,
//! cloning, routing — rather than LabyLang expression interpretation.
//!
//! Results print as a paper-style table and are recorded in
//! `BENCH_throughput.json` (the perf trajectory's seed file). Run via
//! `labyrinth bench-throughput [--smoke]` or
//! `cargo bench --bench throughput` (`LABY_BENCH_QUICK=1` for CI smoke).

use crate::bench_harness::{Bencher, Table};
use crate::exec::{run, ExecConfig};
use crate::frontend::builder::{udf1, udf2, ProgramBuilder};
use crate::frontend::{Program, UdfN};
use crate::opt::OptConfig;
use crate::value::Value;
use crate::workload::registry::Registry;
use std::fmt::Write as _;
use std::sync::Arc;

/// One measured point.
struct Point {
    workload: &'static str,
    workers: usize,
    /// Median wall time of one full run, nanoseconds.
    median_ns: u128,
    /// Source elements processed per second (input cardinality / median).
    elems_per_sec: f64,
    /// Legacy element-at-a-time data plane?
    element_path: bool,
}

fn map_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let m = b.map(v, udf1(|x| Value::I64(x.as_i64().wrapping_mul(3))));
    let n = b.count(m);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn fused_chain_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let m1 = b.map(v, udf1(|x| Value::I64(x.as_i64() + 1)));
    let f = b.filter(m1, udf1(|x| Value::Bool(x.as_i64() % 2 == 0)));
    let m2 = b.map(f, udf1(|x| Value::I64(x.as_i64().wrapping_mul(10))));
    let n = b.count(m2);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn flatmap_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let fm = b.flat_map(
        v,
        UdfN::new("span2", |x: &Value| {
            let k = x.as_i64();
            vec![Value::I64(k), Value::I64(k + 1)]
        }),
    );
    let n = b.count(fm);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn join_probe_program() -> Program {
    let mut b = ProgramBuilder::new();
    let attrs = b.named_source("tp_attrs");
    let probe = b.named_source("tp_pairs");
    let j = b.join(attrs, probe);
    let n = b.count(j);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn reduce_by_key_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let k = b.map(
        v,
        udf1(|x| Value::pair(Value::I64(x.as_i64() % 64), x.clone())),
    );
    let r = b.reduce_by_key(
        k,
        udf2(|a, b| Value::I64(a.as_i64().wrapping_add(b.as_i64()))),
    );
    let n = b.count(r);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn measure(
    bench: &Bencher,
    reg: &Arc<Registry>,
    program: &Program,
    workload: &'static str,
    workers: usize,
    elements: usize,
    element_path: bool,
) -> Point {
    let (graph, _) = crate::compile_with_registry(program, &OptConfig::default(), reg)
        .unwrap_or_else(|e| panic!("{workload}: compile failed: {e}"));
    let cfg = ExecConfig {
        workers,
        registry: reg.clone(),
        element_path,
        ..Default::default()
    };
    let label = format!(
        "{workload} w={workers}{}",
        if element_path { " (element path)" } else { "" }
    );
    let m = bench.run(label, || {
        let out = run(&graph, &cfg).unwrap_or_else(|e| panic!("{workload}: {e}"));
        assert!(!out.collected("n").is_empty(), "{workload}: sink produced nothing");
    });
    let median_ns = m.median().as_nanos().max(1);
    Point {
        workload,
        workers,
        median_ns,
        elems_per_sec: elements as f64 * 1e9 / median_ns as f64,
        element_path,
    }
}

/// Render the measured points as JSON (handwritten — serde is not in the
/// offline registry; see DESIGN.md §2).
fn to_json(
    elements: usize,
    points: &[Point],
    speedup: Option<f64>,
    trace_gate_overhead: Option<f64>,
    checkpoint_gate_overhead: Option<f64>,
    checkpoint_on_overhead: Option<f64>,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"throughput\",");
    let _ = writeln!(s, "  \"elements\": {elements},");
    if let Some(x) = speedup {
        let _ = writeln!(
            s,
            "  \"fused_chain_speedup_vs_element_path\": {x:.3},"
        );
    }
    if let Some(x) = trace_gate_overhead {
        // Fractional slowdown of the disabled-tracer path vs no tracer
        // (acceptance budget: <= 0.02).
        let _ = writeln!(s, "  \"trace_gate_overhead\": {x:.4},");
    }
    if let Some(x) = checkpoint_gate_overhead {
        // Fractional slowdown of an ARMED-but-never-firing fault gate
        // (empty FaultPlan through the recovery wrapper, checkpointing
        // off) vs the plain path (acceptance budget: <= 0.02).
        let _ = writeln!(s, "  \"checkpoint_gate_overhead\": {x:.4},");
    }
    if let Some(x) = checkpoint_on_overhead {
        // Fractional slowdown with checkpoint_every = 1 (frontier
        // tracking + per-bag done reporting + snapshot cuts) — the
        // price of crash-safety when switched ON, not a budget.
        let _ = writeln!(s, "  \"checkpoint_on_overhead\": {x:.4},");
    }
    s.push_str("  \"series\": [\n");
    for (i, p) in points.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"workload\": \"{}\", \"workers\": {}, \"element_path\": {}, \"median_ns\": {}, \"elems_per_sec\": {:.1}}}",
            p.workload, p.workers, p.element_path, p.median_ns, p.elems_per_sec
        );
        s.push_str(if i + 1 < points.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// Run the throughput benchmark; `smoke` shrinks dataset and repetition
/// counts for CI. Writes `BENCH_throughput.json` to the working
/// directory.
pub fn throughput_benchmark(smoke: bool) {
    let elements: usize = if smoke { 20_000 } else { 200_000 };
    let bench = if smoke { Bencher::new(1, 3) } else { Bencher::new(2, 7) };

    // Datasets live in an isolated registry threaded through ExecConfig —
    // nothing leaks into the process-global one.
    let reg = Arc::new(Registry::new());
    reg.put("tp_data", (0..elements as i64).map(Value::I64).collect());
    // Join: a small invariant build side against a full-size probe.
    reg.put(
        "tp_attrs",
        (0..256i64)
            .map(|k| Value::pair(Value::I64(k), Value::I64(k * 100)))
            .collect(),
    );
    reg.put(
        "tp_pairs",
        (0..elements as i64)
            .map(|x| Value::pair(Value::I64(x % 256), Value::I64(x)))
            .collect(),
    );

    let workloads: [(&'static str, Program); 5] = [
        ("map", map_program()),
        ("fused-chain", fused_chain_program()),
        ("flatmap", flatmap_program()),
        ("join-probe", join_probe_program()),
        ("reduceByKey", reduce_by_key_program()),
    ];

    eprintln!("== bench-throughput: {elements} elements/run ==");
    let mut points: Vec<Point> = Vec::new();
    let workers_sweep = [1usize, 2, 4];
    for (name, program) in &workloads {
        for &w in &workers_sweep {
            points.push(measure(&bench, &reg, program, name, w, elements, false));
        }
    }

    // Before/after: the fused map/filter chain through the legacy
    // element-at-a-time data plane (per-element clone + dispatch +
    // routing) vs the batched kernels, single worker — the acceptance
    // series for the batching refactor.
    let (_, fused) = &workloads[1];
    let legacy = measure(&bench, &reg, fused, "fused-chain", 1, elements, true);
    let batched = points
        .iter()
        .find(|p| p.workload == "fused-chain" && p.workers == 1 && !p.element_path)
        .expect("fused-chain w=1 measured");
    let speedup = batched.elems_per_sec / legacy.elems_per_sec.max(1e-9);
    let batched_ns = batched.median_ns;
    eprintln!(
        "fused-chain w=1: batched {:.0} elems/s vs element-path {:.0} elems/s — {speedup:.2}x",
        batched.elems_per_sec, legacy.elems_per_sec
    );
    points.push(legacy);

    // Trace-gate overhead: the same fused chain with a PRESENT but
    // switched-off tracer (one gate load per epoch, a never-taken branch
    // per batch) vs the no-tracer series above. Budget: <= 2%. Reported
    // here and in the JSON rather than hard-asserted — wall-clock ratios
    // on shared CI machines are too noisy for a test gate.
    let trace_gate_overhead = {
        let (graph, _) = crate::compile_with_registry(fused, &OptConfig::default(), &reg)
            .expect("fused-chain compiles");
        let cfg = ExecConfig {
            workers: 1,
            registry: reg.clone(),
            trace: Some(Arc::new(crate::obs::Tracer::new(false))),
            ..Default::default()
        };
        let m = bench.run("fused-chain w=1 (trace gate off)", || {
            let out = run(&graph, &cfg).unwrap_or_else(|e| panic!("trace-gate: {e}"));
            assert!(!out.collected("n").is_empty());
        });
        let gated_ns = m.median().as_nanos().max(1);
        let overhead = gated_ns as f64 / batched_ns as f64 - 1.0;
        eprintln!(
            "trace-gate overhead (disabled tracer vs none), fused-chain w=1: {:+.2}%",
            overhead * 100.0
        );
        overhead
    };

    // Checkpoint/fault-gate overhead: the same fused chain with an ARMED
    // but empty FaultPlan (the per-append fault check runs and the epoch
    // routes through the recovery wrapper; checkpointing stays off) vs
    // the plain series. This is the price every epoch pays when a
    // process-wide LABY_FAULTS plan or a checkpoint cadence is merely
    // configured — budget <= 2%, reported rather than hard-asserted for
    // the same CI-noise reason as the trace gate.
    let (graph_ck, _) = crate::compile_with_registry(fused, &OptConfig::default(), &reg)
        .expect("fused-chain compiles");
    let checkpoint_gate_overhead = {
        let cfg = ExecConfig {
            workers: 1,
            registry: reg.clone(),
            faults: Some(Arc::new(crate::exec::FaultPlan::new())),
            ..Default::default()
        };
        let m = bench.run("fused-chain w=1 (fault gate armed, never fires)", || {
            let out = run(&graph_ck, &cfg).unwrap_or_else(|e| panic!("ckpt-gate: {e}"));
            assert!(!out.collected("n").is_empty());
        });
        let gated_ns = m.median().as_nanos().max(1);
        let overhead = gated_ns as f64 / batched_ns as f64 - 1.0;
        eprintln!(
            "checkpoint-gate overhead (armed, never fires), fused-chain w=1: {:+.2}%",
            overhead * 100.0
        );
        overhead
    };

    // Checkpointing switched ON at the tightest cadence: every decision
    // boundary becomes a quiescent cut (frontier tracking, per-bag done
    // reports, instance snapshots). This is the crash-safety price tag,
    // not a regression budget.
    let checkpoint_on_overhead = {
        let cfg = ExecConfig {
            workers: 1,
            registry: reg.clone(),
            checkpoint_every: Some(1),
            ..Default::default()
        };
        let m = bench.run("fused-chain w=1 (checkpoint_every=1)", || {
            let out = run(&graph_ck, &cfg).unwrap_or_else(|e| panic!("ckpt-on: {e}"));
            assert!(!out.collected("n").is_empty());
        });
        let on_ns = m.median().as_nanos().max(1);
        let overhead = on_ns as f64 / batched_ns as f64 - 1.0;
        eprintln!(
            "checkpointing-on overhead (checkpoint_every=1), fused-chain w=1: {:+.2}%",
            overhead * 100.0
        );
        overhead
    };

    // Paper-style table: workloads × worker counts (median run time).
    let mut table = Table::new(
        "Data-plane throughput (median run time; see BENCH_throughput.json for elems/sec)",
        "workload",
        workers_sweep.iter().map(|w| format!("w={w}")).collect(),
    );
    for (name, _) in &workloads {
        let cells = workers_sweep
            .iter()
            .map(|&w| {
                points
                    .iter()
                    .find(|p| p.workload == *name && p.workers == w && !p.element_path)
                    .map(|p| std::time::Duration::from_nanos(p.median_ns as u64))
            })
            .collect();
        table.push_row(*name, cells);
    }
    table.print();

    let json = to_json(
        elements,
        &points,
        Some(speedup),
        Some(trace_gate_overhead),
        Some(checkpoint_gate_overhead),
        Some(checkpoint_on_overhead),
    );
    let path = "BENCH_throughput.json";
    std::fs::write(path, &json).unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");
}
