//! `bench-throughput` — data-plane throughput in **elements per second**
//! for the hot operator kernels (map, fused map/filter chain, hash-join
//! probe, reduceByKey) at worker counts {1, 2, 4}, plus a before/after
//! series pitting the batched `Transformation` interface against the
//! legacy element-at-a-time path (`ExecConfig::element_path`).
//!
//! Programs are built with the Rust builder frontend (native-closure
//! UDFs), so the numbers measure the data plane — per-element dispatch,
//! cloning, routing — rather than LabyLang expression interpretation.
//! The exception is the `typed_kernels` A/B series, which uses parsed
//! (expr-carrying) UDFs on purpose: those are the only UDFs the
//! `opt::types` inference can compile into monomorphic columnar kernels,
//! so the series pits the typed columnar plane (`--columnar always`)
//! against the dynamic `Value` path (`--columnar never`) on the same
//! programs a LabyLang user would write.
//!
//! An `iter_cost` section charts per-iteration marginal cost for
//! loop-carried workloads under `opt::delta` vs full recompute:
//! incremental visit-count (delta-eligible; steady-state cost tracks the
//! day's changed rows) and dense PageRank (structurally ineligible; the
//! pass falls back and both curves coincide, `delta_loops == 0`).
//!
//! Results print as a paper-style table and are recorded in
//! `BENCH_throughput.json` (the perf trajectory's seed file). Run via
//! `labyrinth bench-throughput [--smoke]` or
//! `cargo bench --bench throughput` (`LABY_BENCH_QUICK=1` for CI smoke).

use crate::bench_harness::{Bencher, Table};
use crate::exec::{run, ExecConfig};
use crate::frontend::builder::{udf1, udf2, ProgramBuilder};
use crate::frontend::{Program, UdfN};
use crate::opt::OptConfig;
use crate::value::Value;
use crate::workload::registry::Registry;
use std::fmt::Write as _;
use std::sync::Arc;

/// One measured point.
struct Point {
    workload: &'static str,
    workers: usize,
    /// Median wall time of one full run, nanoseconds.
    median_ns: u128,
    /// Source elements processed per second (input cardinality / median).
    elems_per_sec: f64,
    /// Legacy element-at-a-time data plane?
    element_path: bool,
}

fn map_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let m = b.map(v, udf1(|x| Value::I64(x.as_i64().wrapping_mul(3))));
    let n = b.count(m);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn fused_chain_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let m1 = b.map(v, udf1(|x| Value::I64(x.as_i64() + 1)));
    let f = b.filter(m1, udf1(|x| Value::Bool(x.as_i64() % 2 == 0)));
    let m2 = b.map(f, udf1(|x| Value::I64(x.as_i64().wrapping_mul(10))));
    let n = b.count(m2);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn flatmap_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let fm = b.flat_map(
        v,
        UdfN::new("span2", |x: &Value| {
            let k = x.as_i64();
            vec![Value::I64(k), Value::I64(k + 1)]
        }),
    );
    let n = b.count(fm);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn join_probe_program() -> Program {
    let mut b = ProgramBuilder::new();
    let attrs = b.named_source("tp_attrs");
    let probe = b.named_source("tp_pairs");
    let j = b.join(attrs, probe);
    let n = b.count(j);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn reduce_by_key_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let k = b.map(
        v,
        udf1(|x| Value::pair(Value::I64(x.as_i64() % 64), x.clone())),
    );
    let r = b.reduce_by_key(
        k,
        udf2(|a, b| Value::I64(a.as_i64().wrapping_add(b.as_i64()))),
    );
    let n = b.count(r);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

/// Parse a LabyLang lambda into an expr-carrying UDF — the form
/// `opt::types::compile_udf1` can monomorphize. Builder native closures
/// deliberately carry no expr, so they can never take the typed path.
fn parsed_udf1(src: &str) -> crate::frontend::Udf1 {
    use crate::frontend::{ast, interp_expr, lexer::lex, parser};
    let ast = parser::parse(&lex(&format!("x = {src};")).unwrap()).unwrap();
    match &ast.stmts[0] {
        ast::Stmt::Assign(_, ast::Expr::Lambda(ps, body)) => {
            interp_expr::compile_udf1(ps.clone(), (**body).clone(), "benchλ".into()).unwrap()
        }
        other => panic!("not a lambda: {other:?}"),
    }
}

fn parsed_udf2(src: &str) -> crate::frontend::Udf2 {
    use crate::frontend::{ast, interp_expr, lexer::lex, parser};
    let ast = parser::parse(&lex(&format!("x = {src};")).unwrap()).unwrap();
    match &ast.stmts[0] {
        ast::Stmt::Assign(_, ast::Expr::Lambda(ps, body)) => {
            interp_expr::compile_udf2(ps.clone(), (**body).clone(), "benchλ".into()).unwrap()
        }
        other => panic!("not a lambda: {other:?}"),
    }
}

fn typed_map_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let m = b.map(v, parsed_udf1("|x| x * 3"));
    let n = b.count(m);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn typed_fused_chain_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let m1 = b.map(v, parsed_udf1("|x| x + 1"));
    let f = b.filter(m1, parsed_udf1("|x| x % 2 == 0"));
    let m2 = b.map(f, parsed_udf1("|x| x * 10"));
    let n = b.count(m2);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

/// Filter-heavy chain: two typed filters bracketing maps. The selection
/// bitmap makes this the series where masked execution shows up —
/// interior filters clear bits instead of compacting, so survivors move
/// once per batch instead of once per filter stage.
fn typed_filter_map_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let f1 = b.filter(v, parsed_udf1("|x| x % 2 == 0"));
    let m1 = b.map(f1, parsed_udf1("|x| x + 100"));
    let f2 = b.filter(m1, parsed_udf1("|x| x % 3 == 0"));
    let m2 = b.map(f2, parsed_udf1("|x| x * 2"));
    let n = b.count(m2);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

fn typed_reduce_by_key_program() -> Program {
    let mut b = ProgramBuilder::new();
    let v = b.named_source("tp_data");
    let k = b.map(v, parsed_udf1("|x| pair(x % 64, x)"));
    let r = b.reduce_by_key(k, parsed_udf2("|a, b| a + b"));
    let n = b.count(r);
    let nb = b.lift_scalar(n);
    b.collect(nb, "n");
    b.finish()
}

/// One typed-vs-dynamic A/B point (`opt.columnar` forced on vs off).
struct TypedPoint {
    workload: &'static str,
    /// Edges with a concrete inferred `ElemType` in the columnar plan —
    /// asserted nonzero so the A leg can't silently measure the B path.
    typed_edges: usize,
    columnar_ns: u128,
    dynamic_ns: u128,
    /// dynamic / columnar median — the typed-kernel speedup.
    speedup: f64,
}

/// Columnar vs dynamic on expr-carrying map / fused-chain / reduceByKey:
/// the same compiled plan shape, single worker, with only the
/// `opt.columnar` gate flipped. The acceptance target for the typed data
/// plane is >= 1.5x on the fused numeric chain.
fn typed_kernels_bench(bench: &Bencher, reg: &Arc<Registry>) -> Vec<TypedPoint> {
    use crate::opt::ColumnarGate;
    let workloads: [(&'static str, Program); 4] = [
        ("map", typed_map_program()),
        ("fused-chain", typed_fused_chain_program()),
        ("filter-map", typed_filter_map_program()),
        ("reduceByKey", typed_reduce_by_key_program()),
    ];
    let mut out = Vec::new();
    for (name, program) in &workloads {
        let leg = |gate: ColumnarGate, tag: &str| -> (u128, usize) {
            let ocfg = OptConfig { columnar: gate, ..Default::default() };
            let (graph, report) = crate::compile_with_registry(program, &ocfg, reg)
                .unwrap_or_else(|e| panic!("typed {name}: compile failed: {e}"));
            let cfg = ExecConfig { workers: 1, registry: reg.clone(), ..Default::default() };
            let m = bench.run(format!("typed {name} w=1 ({tag})"), || {
                let res = run(&graph, &cfg).unwrap_or_else(|e| panic!("typed {name}: {e}"));
                assert!(!res.collected("n").is_empty(), "typed {name}: sink produced nothing");
            });
            (m.median().as_nanos().max(1), report.typed_edges)
        };
        let (columnar_ns, typed_edges) = leg(ColumnarGate::Always, "columnar");
        let (dynamic_ns, _) = leg(ColumnarGate::Never, "dynamic");
        assert!(
            typed_edges > 0,
            "typed {name}: inference typed no edges — the columnar leg would measure the dynamic path"
        );
        let speedup = dynamic_ns as f64 / columnar_ns as f64;
        eprintln!(
            "typed-kernels {name} w=1: columnar {columnar_ns}ns vs dynamic {dynamic_ns}ns — {speedup:.2}x ({typed_edges} typed edges)"
        );
        out.push(TypedPoint { workload: *name, typed_edges, columnar_ns, dynamic_ns, speedup });
    }
    out
}

fn measure(
    bench: &Bencher,
    reg: &Arc<Registry>,
    program: &Program,
    workload: &'static str,
    workers: usize,
    elements: usize,
    element_path: bool,
) -> Point {
    let (graph, _) = crate::compile_with_registry(program, &OptConfig::default(), reg)
        .unwrap_or_else(|e| panic!("{workload}: compile failed: {e}"));
    let cfg = ExecConfig {
        workers,
        registry: reg.clone(),
        element_path,
        ..Default::default()
    };
    let label = format!(
        "{workload} w={workers}{}",
        if element_path { " (element path)" } else { "" }
    );
    let m = bench.run(label, || {
        let out = run(&graph, &cfg).unwrap_or_else(|e| panic!("{workload}: {e}"));
        assert!(!out.collected("n").is_empty(), "{workload}: sink produced nothing");
    });
    let median_ns = m.median().as_nanos().max(1);
    Point {
        workload,
        workers,
        median_ns,
        elems_per_sec: elements as f64 * 1e9 / median_ns as f64,
        element_path,
    }
}

/// One workload's per-iteration cost curve, delta vs full recompute
/// (the `opt::delta` acceptance series).
struct IterCost {
    workload: &'static str,
    /// `ExplainReport::delta_loops` under `DeltaGate::Always` — 0 means
    /// the safety analysis (correctly) fell back to full recompute and
    /// the two curves should coincide.
    delta_loops: usize,
    /// Iteration counts measured (total wall time per count; the
    /// marginal series below differences consecutive windows).
    iters: Vec<i64>,
    /// Marginal nanoseconds per iteration in window `k`
    /// (`(t[k+1]-t[k]) / (iters[k+1]-iters[k])`), full recompute.
    marginal_full_ns: Vec<u128>,
    /// Same, with the delta pass enabled.
    marginal_delta_ns: Vec<u128>,
    /// Last-window full/delta marginal ratio — steady-state speedup.
    steady_speedup: f64,
}

/// Difference consecutive total-time measurements into per-iteration
/// marginal costs.
fn marginals(iters: &[i64], totals: &[u128]) -> Vec<u128> {
    iters
        .windows(2)
        .zip(totals.windows(2))
        .map(|(iw, tw)| tw[1].saturating_sub(tw[0]) / (iw[1] - iw[0]).max(1) as u128)
        .collect()
}

/// Per-iteration cost curves: incremental visit-count (delta-eligible —
/// steady-state iteration cost tracks the day's changed rows, not the
/// accumulated history) and dense power-iteration PageRank (structurally
/// delta-INeligible — the carried ranks feed a join probe, so the pass
/// proves nothing and honestly falls back; both curves coincide).
fn iter_cost_bench(bench: &Bencher, smoke: bool) -> Vec<IterCost> {
    use crate::opt::DeltaGate;
    let reg = Arc::new(Registry::new());
    let (per_day, iters): (usize, Vec<i64>) = if smoke {
        (10_000, vec![2, 4, 6, 8, 10])
    } else {
        (20_000, vec![2, 4, 6, 8, 10, 12])
    };
    // Each day visits a fresh key range, so the solution set grows
    // linearly while the per-day change stays constant.
    let max_days = *iters.last().unwrap();
    for d in 1..=max_days {
        let base = (d - 1) * per_day as i64;
        reg.put(
            format!("it_visits{d}"),
            (base..base + per_day as i64).map(Value::I64).collect(),
        );
    }
    // Dense PageRank adjacency: (src, (dst, 1/outdeg)) with a
    // deterministic LCG edge sample.
    let pages: i64 = if smoke { 2_000 } else { 10_000 };
    let edges_n: usize = if smoke { 20_000 } else { 100_000 };
    let mut outdeg = vec![0usize; pages as usize];
    let mut raw = Vec::with_capacity(edges_n);
    let mut x = 0x9E37_79B9_7F4A_7C15u64;
    let mut step = || {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (x >> 33) % pages as u64
    };
    for _ in 0..edges_n {
        let (s, t) = (step() as usize, step() as usize);
        raw.push((s, t));
        outdeg[s] += 1;
    }
    let adj: Vec<Value> = raw
        .iter()
        .map(|&(s, t)| {
            Value::pair(
                Value::I64(s as i64),
                Value::pair(Value::I64(t as i64), Value::F64(1.0 / outdeg[s] as f64)),
            )
        })
        .collect();
    reg.put("it_adj1", adj);

    let curve = |mk: &dyn Fn(i64) -> Program, gate: DeltaGate, label: &str| -> (usize, Vec<u128>) {
        let mut totals = Vec::new();
        let mut delta_loops = 0;
        for &d in &iters {
            let p = mk(d);
            let ocfg = OptConfig { delta: gate, ..Default::default() };
            let (graph, report) = crate::compile_with_registry(&p, &ocfg, &reg)
                .unwrap_or_else(|e| panic!("{label}: compile failed: {e}"));
            delta_loops = report.delta_loops;
            let cfg = ExecConfig { workers: 2, registry: reg.clone(), ..Default::default() };
            let m = bench.run(format!("{label} iters={d}"), || {
                run(&graph, &cfg).unwrap_or_else(|e| panic!("{label}: {e}"));
            });
            totals.push(m.median().as_nanos().max(1));
        }
        (delta_loops, totals)
    };

    let vc = |d: i64| crate::programs::visit_count_incremental(d, "it_");
    let (vc_loops, vc_delta) = curve(&vc, DeltaGate::Always, "iter-cost visit-count (delta)");
    let (_, vc_full) = curve(&vc, DeltaGate::Never, "iter-cost visit-count (full)");
    let vc_md = marginals(&iters, &vc_delta);
    let vc_mf = marginals(&iters, &vc_full);
    let vc_speedup =
        *vc_mf.last().unwrap() as f64 / (*vc_md.last().unwrap()).max(1) as f64;

    let pr = |d: i64| crate::programs::pagerank_nested(1, d, pages as usize, "it_");
    let (pr_loops, pr_delta) = curve(&pr, DeltaGate::Always, "iter-cost pagerank (delta cfg)");
    let (_, pr_full) = curve(&pr, DeltaGate::Never, "iter-cost pagerank (full)");
    let pr_md = marginals(&iters, &pr_delta);
    let pr_mf = marginals(&iters, &pr_full);
    let pr_speedup =
        *pr_mf.last().unwrap() as f64 / (*pr_md.last().unwrap()).max(1) as f64;

    eprintln!(
        "iter-cost visit-count: delta_loops={vc_loops}, steady-state marginal {}ns (delta) vs {}ns (full) — {vc_speedup:.1}x",
        vc_md.last().unwrap(),
        vc_mf.last().unwrap()
    );
    eprintln!(
        "iter-cost pagerank: delta_loops={pr_loops} (structural fallback), steady-state marginal {}ns (delta cfg) vs {}ns (full) — {pr_speedup:.2}x",
        pr_md.last().unwrap(),
        pr_mf.last().unwrap()
    );

    vec![
        IterCost {
            workload: "visit-count-incremental",
            delta_loops: vc_loops,
            iters: iters.clone(),
            marginal_full_ns: vc_mf,
            marginal_delta_ns: vc_md,
            steady_speedup: vc_speedup,
        },
        IterCost {
            workload: "pagerank",
            delta_loops: pr_loops,
            iters,
            marginal_full_ns: pr_mf,
            marginal_delta_ns: pr_md,
            steady_speedup: pr_speedup,
        },
    ]
}

/// Render the measured points as JSON (handwritten — serde is not in the
/// offline registry; see DESIGN.md §2).
fn to_json(
    elements: usize,
    points: &[Point],
    speedup: Option<f64>,
    trace_gate_overhead: Option<f64>,
    checkpoint_gate_overhead: Option<f64>,
    checkpoint_on_overhead: Option<f64>,
    typed_kernels: &[TypedPoint],
    iter_cost: &[IterCost],
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"throughput\",");
    let _ = writeln!(s, "  \"elements\": {elements},");
    if let Some(x) = speedup {
        let _ = writeln!(
            s,
            "  \"fused_chain_speedup_vs_element_path\": {x:.3},"
        );
    }
    if let Some(x) = trace_gate_overhead {
        // Fractional slowdown of the disabled-tracer path vs no tracer
        // (acceptance budget: <= 0.02).
        let _ = writeln!(s, "  \"trace_gate_overhead\": {x:.4},");
    }
    if let Some(x) = checkpoint_gate_overhead {
        // Fractional slowdown of an ARMED-but-never-firing fault gate
        // (empty FaultPlan through the recovery wrapper, checkpointing
        // off) vs the plain path (acceptance budget: <= 0.02).
        let _ = writeln!(s, "  \"checkpoint_gate_overhead\": {x:.4},");
    }
    if let Some(x) = checkpoint_on_overhead {
        // Fractional slowdown with checkpoint_every = 1 (frontier
        // tracking + per-bag done reporting + snapshot cuts) — the
        // price of crash-safety when switched ON, not a budget.
        let _ = writeln!(s, "  \"checkpoint_on_overhead\": {x:.4},");
    }
    if !typed_kernels.is_empty() {
        // Typed columnar kernels vs the dynamic Value path on
        // expr-carrying UDFs (`opt.columnar` always vs never), w=1.
        s.push_str("  \"typed_kernels\": [\n");
        for (i, t) in typed_kernels.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"workload\": \"{}\", \"typed_edges\": {}, \"columnar_ns\": {}, \"dynamic_ns\": {}, \"speedup\": {:.3}}}",
                t.workload, t.typed_edges, t.columnar_ns, t.dynamic_ns, t.speedup
            );
            s.push_str(if i + 1 < typed_kernels.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ],\n");
    }
    if !iter_cost.is_empty() {
        // Per-iteration marginal cost curves, delta vs full recompute
        // (`opt::delta`). `delta_loops == 0` marks an honest fallback.
        s.push_str("  \"iter_cost\": [\n");
        for (i, c) in iter_cost.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"workload\": \"{}\", \"delta_loops\": {}, \"iters\": {:?}, \"marginal_full_ns\": {:?}, \"marginal_delta_ns\": {:?}, \"steady_speedup\": {:.2}}}",
                c.workload,
                c.delta_loops,
                c.iters,
                c.marginal_full_ns,
                c.marginal_delta_ns,
                c.steady_speedup
            );
            s.push_str(if i + 1 < iter_cost.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ],\n");
    }
    s.push_str("  \"series\": [\n");
    for (i, p) in points.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"workload\": \"{}\", \"workers\": {}, \"element_path\": {}, \"median_ns\": {}, \"elems_per_sec\": {:.1}}}",
            p.workload, p.workers, p.element_path, p.median_ns, p.elems_per_sec
        );
        s.push_str(if i + 1 < points.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// Run the throughput benchmark; `smoke` shrinks dataset and repetition
/// counts for CI. Writes `BENCH_throughput.json` to the working
/// directory.
pub fn throughput_benchmark(smoke: bool) {
    let elements: usize = if smoke { 20_000 } else { 200_000 };
    let bench = if smoke { Bencher::new(1, 3) } else { Bencher::new(2, 7) };

    // Datasets live in an isolated registry threaded through ExecConfig —
    // nothing leaks into the process-global one.
    let reg = Arc::new(Registry::new());
    reg.put("tp_data", (0..elements as i64).map(Value::I64).collect());
    // Join: a small invariant build side against a full-size probe.
    reg.put(
        "tp_attrs",
        (0..256i64)
            .map(|k| Value::pair(Value::I64(k), Value::I64(k * 100)))
            .collect(),
    );
    reg.put(
        "tp_pairs",
        (0..elements as i64)
            .map(|x| Value::pair(Value::I64(x % 256), Value::I64(x)))
            .collect(),
    );

    let workloads: [(&'static str, Program); 5] = [
        ("map", map_program()),
        ("fused-chain", fused_chain_program()),
        ("flatmap", flatmap_program()),
        ("join-probe", join_probe_program()),
        ("reduceByKey", reduce_by_key_program()),
    ];

    eprintln!("== bench-throughput: {elements} elements/run ==");
    let mut points: Vec<Point> = Vec::new();
    let workers_sweep = [1usize, 2, 4];
    for (name, program) in &workloads {
        for &w in &workers_sweep {
            points.push(measure(&bench, &reg, program, name, w, elements, false));
        }
    }

    // Before/after: the fused map/filter chain through the legacy
    // element-at-a-time data plane (per-element clone + dispatch +
    // routing) vs the batched kernels, single worker — the acceptance
    // series for the batching refactor.
    let (_, fused) = &workloads[1];
    let legacy = measure(&bench, &reg, fused, "fused-chain", 1, elements, true);
    let batched = points
        .iter()
        .find(|p| p.workload == "fused-chain" && p.workers == 1 && !p.element_path)
        .expect("fused-chain w=1 measured");
    let speedup = batched.elems_per_sec / legacy.elems_per_sec.max(1e-9);
    let batched_ns = batched.median_ns;
    eprintln!(
        "fused-chain w=1: batched {:.0} elems/s vs element-path {:.0} elems/s — {speedup:.2}x",
        batched.elems_per_sec, legacy.elems_per_sec
    );
    points.push(legacy);

    // Trace-gate overhead: the same fused chain with a PRESENT but
    // switched-off tracer (one gate load per epoch, a never-taken branch
    // per batch) vs the no-tracer series above. Budget: <= 2%. Reported
    // here and in the JSON rather than hard-asserted — wall-clock ratios
    // on shared CI machines are too noisy for a test gate.
    let trace_gate_overhead = {
        let (graph, _) = crate::compile_with_registry(fused, &OptConfig::default(), &reg)
            .expect("fused-chain compiles");
        let cfg = ExecConfig {
            workers: 1,
            registry: reg.clone(),
            trace: Some(Arc::new(crate::obs::Tracer::new(false))),
            ..Default::default()
        };
        let m = bench.run("fused-chain w=1 (trace gate off)", || {
            let out = run(&graph, &cfg).unwrap_or_else(|e| panic!("trace-gate: {e}"));
            assert!(!out.collected("n").is_empty());
        });
        let gated_ns = m.median().as_nanos().max(1);
        let overhead = gated_ns as f64 / batched_ns as f64 - 1.0;
        eprintln!(
            "trace-gate overhead (disabled tracer vs none), fused-chain w=1: {:+.2}%",
            overhead * 100.0
        );
        overhead
    };

    // Checkpoint/fault-gate overhead: the same fused chain with an ARMED
    // but empty FaultPlan (the per-append fault check runs and the epoch
    // routes through the recovery wrapper; checkpointing stays off) vs
    // the plain series. This is the price every epoch pays when a
    // process-wide LABY_FAULTS plan or a checkpoint cadence is merely
    // configured — budget <= 2%, reported rather than hard-asserted for
    // the same CI-noise reason as the trace gate.
    let (graph_ck, _) = crate::compile_with_registry(fused, &OptConfig::default(), &reg)
        .expect("fused-chain compiles");
    let checkpoint_gate_overhead = {
        let cfg = ExecConfig {
            workers: 1,
            registry: reg.clone(),
            faults: Some(Arc::new(crate::exec::FaultPlan::new())),
            ..Default::default()
        };
        let m = bench.run("fused-chain w=1 (fault gate armed, never fires)", || {
            let out = run(&graph_ck, &cfg).unwrap_or_else(|e| panic!("ckpt-gate: {e}"));
            assert!(!out.collected("n").is_empty());
        });
        let gated_ns = m.median().as_nanos().max(1);
        let overhead = gated_ns as f64 / batched_ns as f64 - 1.0;
        eprintln!(
            "checkpoint-gate overhead (armed, never fires), fused-chain w=1: {:+.2}%",
            overhead * 100.0
        );
        overhead
    };

    // Checkpointing switched ON at the tightest cadence: every decision
    // boundary becomes a quiescent cut (frontier tracking, per-bag done
    // reports, instance snapshots). This is the crash-safety price tag,
    // not a regression budget.
    let checkpoint_on_overhead = {
        let cfg = ExecConfig {
            workers: 1,
            registry: reg.clone(),
            checkpoint_every: Some(1),
            ..Default::default()
        };
        let m = bench.run("fused-chain w=1 (checkpoint_every=1)", || {
            let out = run(&graph_ck, &cfg).unwrap_or_else(|e| panic!("ckpt-on: {e}"));
            assert!(!out.collected("n").is_empty());
        });
        let on_ns = m.median().as_nanos().max(1);
        let overhead = on_ns as f64 / batched_ns as f64 - 1.0;
        eprintln!(
            "checkpointing-on overhead (checkpoint_every=1), fused-chain w=1: {:+.2}%",
            overhead * 100.0
        );
        overhead
    };

    // Paper-style table: workloads × worker counts (median run time).
    let mut table = Table::new(
        "Data-plane throughput (median run time; see BENCH_throughput.json for elems/sec)",
        "workload",
        workers_sweep.iter().map(|w| format!("w={w}")).collect(),
    );
    for (name, _) in &workloads {
        let cells = workers_sweep
            .iter()
            .map(|&w| {
                points
                    .iter()
                    .find(|p| p.workload == *name && p.workers == w && !p.element_path)
                    .map(|p| std::time::Duration::from_nanos(p.median_ns as u64))
            })
            .collect();
        table.push_row(*name, cells);
    }
    table.print();

    // Per-iteration cost curves for the delta-incremental engine:
    // steady-state iteration cost should track changed rows, not the
    // accumulated solution set (and PageRank should show the honest
    // structural fallback).
    let iter_cost = iter_cost_bench(&bench, smoke);

    // Typed columnar vs dynamic A/B on the expr-carrying variants of the
    // hot chains (the `opt::types` acceptance series).
    let typed_kernels = typed_kernels_bench(&bench, &reg);

    let json = to_json(
        elements,
        &points,
        Some(speedup),
        Some(trace_gate_overhead),
        Some(checkpoint_gate_overhead),
        Some(checkpoint_on_overhead),
        &typed_kernels,
        &iter_cost,
    );
    let path = "BENCH_throughput.json";
    std::fs::write(path, &json).unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");
}
