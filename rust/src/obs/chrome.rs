//! Chrome-trace (Perfetto / `chrome://tracing`) JSON export.
//!
//! The tracer records **complete spans** (`ts`, `dur`); this module
//! lowers them to the Trace Event Format's duration events — balanced
//! `B`/`E` pairs per lane — plus `M` metadata events naming each lane.
//! JSON is handwritten (serde is not in the offline registry; see
//! DESIGN.md §2), and [`validate`] checks the structural invariants the
//! CI `trace-smoke` leg also enforces on the written file: every `B`
//! has a matching `E` on the same lane with the same name, timestamps
//! are monotonic per lane, and durations are non-negative.
//!
//! Within one lane spans are naturally nested or disjoint (each lane is
//! one thread recording sequential work, and enclosing spans — epoch
//! around supersteps — start earlier and end later). The lowering is
//! still defensive: a child that outlives its parent is clipped to the
//! parent's end, so the output is balanced even on malformed input.

use super::{SpanKind, Trace, TraceEvent};
use crate::dataflow::DataflowGraph;
use std::fmt::Write as _;

/// One lowered Trace-Event-Format record.
#[derive(Clone, Debug, PartialEq)]
pub struct ChromeEvent {
    /// Phase: `'B'` (begin), `'E'` (end), or `'M'` (metadata).
    pub ph: char,
    /// Event name (operator mnemonic, `superstep 3`, `epoch`, …).
    pub name: String,
    /// Category: `engine`, `node`, or `serve`.
    pub cat: &'static str,
    /// Timestamp in nanoseconds since the tracer origin (serialized as
    /// fractional microseconds, the format's native unit).
    pub ts_ns: u64,
    /// Lane (serialized as `tid`).
    pub lane: u32,
    /// Extra `args` rendered as `"k":v` pairs (numbers only).
    pub args: Vec<(&'static str, u64)>,
}

/// Resolve a span kind to `(category, name, args)`. Node names come
/// from the graph when one is supplied, raw ids otherwise.
pub fn span_label(kind: &SpanKind, graph: Option<&DataflowGraph>) -> (&'static str, String, Vec<(&'static str, u64)>) {
    let node_name = |id: u32| -> String {
        match graph.and_then(|g| g.nodes.get(id as usize)) {
            Some(n) => format!("{} {}", n.name, n.op.mnemonic()),
            None => format!("node {id}"),
        }
    };
    match *kind {
        SpanKind::Epoch => ("engine", "epoch".into(), vec![]),
        SpanKind::Dispatch => ("engine", "dispatch".into(), vec![]),
        SpanKind::Drain => ("engine", "drain".into(), vec![]),
        SpanKind::Superstep { pos, block, blocks } => (
            "engine",
            if blocks > 1 {
                format!("steps {pos}..{} (bb{block}..)", pos + blocks - 1)
            } else {
                format!("step {pos} (bb{block})")
            },
            vec![("pos", pos as u64), ("blocks", blocks as u64)],
        ),
        SpanKind::NodeBatch { node, step } => {
            ("node", node_name(node), vec![("node", node as u64), ("step", step as u64)])
        }
        SpanKind::NodeClose { node, step } => (
            "node",
            format!("{} close", node_name(node)),
            vec![("node", node as u64), ("step", step as u64)],
        ),
        SpanKind::Generate { node, step } => (
            "node",
            format!("{} generate", node_name(node)),
            vec![("node", node as u64), ("step", step as u64)],
        ),
        SpanKind::Checkpoint { pos } => {
            ("engine", format!("checkpoint @{pos}"), vec![("pos", pos as u64)])
        }
        SpanKind::Recover { pos } => {
            ("engine", format!("recover @{pos}"), vec![("pos", pos as u64)])
        }
        SpanKind::Queue { job } => ("serve", format!("queue job {job}"), vec![("job", job)]),
        SpanKind::Compile { job } => ("serve", format!("compile job {job}"), vec![("job", job)]),
        SpanKind::Bind { job } => ("serve", format!("bind job {job}"), vec![("job", job)]),
        SpanKind::JobRun { job } => ("serve", format!("run job {job}"), vec![("job", job)]),
        SpanKind::Request { job } => ("serve", format!("request {job}"), vec![("job", job)]),
        SpanKind::PoolResize { lane, from, to } => (
            "serve",
            format!("lane {lane} pool {from} -> {to} workers"),
            vec![("lane", lane as u64), ("from", from as u64), ("to", to as u64)],
        ),
    }
}

/// Lower a trace to balanced `B`/`E` (+ lane-name `M`) events.
pub fn chrome_events(trace: &Trace, graph: Option<&DataflowGraph>) -> Vec<ChromeEvent> {
    let mut out: Vec<ChromeEvent> = Vec::with_capacity(trace.events.len() * 2 + trace.lanes.len());
    for (lane, name) in &trace.lanes {
        out.push(ChromeEvent {
            ph: 'M',
            name: "thread_name".into(),
            cat: "__metadata",
            ts_ns: 0,
            lane: *lane,
            args: vec![],
        });
        // Metadata args carry the lane name; stash it through the name
        // field of a paired record instead of widening `args` to
        // strings: the serializer special-cases `M` events.
        let last = out.last_mut().unwrap();
        last.name = format!("thread_name\u{0}{name}");
    }

    // Per lane: sort by (ts, longest-first) so parents precede children,
    // then emit with an open-span stack, clipping children to parents.
    let mut lanes: Vec<u32> = trace.events.iter().map(|e| e.lane).collect();
    lanes.sort_unstable();
    lanes.dedup();
    for lane in lanes {
        let mut evs: Vec<&TraceEvent> =
            trace.events.iter().filter(|e| e.lane == lane).collect();
        evs.sort_by(|a, b| a.ts.cmp(&b.ts).then(b.dur.cmp(&a.dur)));
        // Stack of (end_ts, name, cat) for spans currently open.
        let mut open: Vec<(u64, String, &'static str)> = Vec::new();
        for e in evs {
            // Close every open span that ends at or before this start.
            while open.last().map_or(false, |(end, _, _)| *end <= e.ts) {
                let (end, name, cat) = open.pop().unwrap();
                out.push(ChromeEvent { ph: 'E', name, cat, ts_ns: end, lane, args: vec![] });
            }
            let (cat, name, args) = span_label(&e.kind, graph);
            // Clip to the innermost open parent so nesting stays proper.
            let mut end = e.ts.saturating_add(e.dur);
            if let Some((parent_end, _, _)) = open.last() {
                end = end.min(*parent_end);
            }
            out.push(ChromeEvent { ph: 'B', name: name.clone(), cat, ts_ns: e.ts, lane, args });
            open.push((end, name, cat));
        }
        while let Some((end, name, cat)) = open.pop() {
            out.push(ChromeEvent { ph: 'E', name, cat, ts_ns: end, lane, args: vec![] });
        }
    }
    out
}

/// Serialize lowered events as a Trace-Event-Format JSON object
/// (`{"traceEvents": [...]}`), loadable in Perfetto (ui.perfetto.dev)
/// and `chrome://tracing`.
pub fn render(events: &[ChromeEvent]) -> String {
    let mut s = String::with_capacity(events.len() * 96 + 64);
    s.push_str("{\"traceEvents\":[\n");
    for (i, e) in events.iter().enumerate() {
        let ts_us = e.ts_ns as f64 / 1_000.0;
        if e.ph == 'M' {
            // `name\0lane-name` carries the lane label (see above).
            let (name, lane_name) = e.name.split_once('\u{0}').unwrap_or((e.name.as_str(), "?"));
            let _ = write!(
                s,
                "  {{\"ph\":\"M\",\"name\":\"{}\",\"pid\":0,\"tid\":{},\"args\":{{\"name\":\"{}\"}}}}",
                escape(name),
                e.lane,
                escape(lane_name),
            );
        } else {
            let _ = write!(
                s,
                "  {{\"ph\":\"{}\",\"name\":\"{}\",\"cat\":\"{}\",\"ts\":{ts_us:.3},\"pid\":0,\"tid\":{}",
                e.ph,
                escape(&e.name),
                e.cat,
                e.lane,
            );
            if e.ph == 'B' && !e.args.is_empty() {
                s.push_str(",\"args\":{");
                for (j, (k, v)) in e.args.iter().enumerate() {
                    if j > 0 {
                        s.push(',');
                    }
                    let _ = write!(s, "\"{k}\":{v}");
                }
                s.push('}');
            }
            s.push('}');
        }
        s.push_str(if i + 1 < events.len() { ",\n" } else { "\n" });
    }
    s.push_str("],\"displayTimeUnit\":\"ms\"}\n");
    s
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Structural validation of lowered events: per lane, `B`/`E` balance
/// with matching names (proper nesting), monotonic non-decreasing
/// timestamps, and no unmatched end. Returns the offending reason.
pub fn validate(events: &[ChromeEvent]) -> Result<(), String> {
    use std::collections::HashMap;
    let mut last_ts: HashMap<u32, u64> = HashMap::new();
    let mut stacks: HashMap<u32, Vec<&str>> = HashMap::new();
    for e in events {
        if e.ph == 'M' {
            continue;
        }
        let last = last_ts.entry(e.lane).or_insert(0);
        if e.ts_ns < *last {
            return Err(format!(
                "lane {}: timestamp went backwards ({} -> {})",
                e.lane, last, e.ts_ns
            ));
        }
        *last = e.ts_ns;
        let stack = stacks.entry(e.lane).or_default();
        match e.ph {
            'B' => stack.push(&e.name),
            'E' => match stack.pop() {
                Some(open) if open == e.name => {}
                Some(open) => {
                    return Err(format!(
                        "lane {}: E \"{}\" does not match open B \"{}\"",
                        e.lane, e.name, open
                    ))
                }
                None => return Err(format!("lane {}: E \"{}\" with no open B", e.lane, e.name)),
            },
            other => return Err(format!("unexpected phase '{other}'")),
        }
    }
    for (lane, stack) in stacks {
        if !stack.is_empty() {
            return Err(format!("lane {lane}: {} unclosed B events", stack.len()));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{SpanKind, Trace, TraceEvent};

    fn ev(ts: u64, dur: u64, lane: u32, kind: SpanKind) -> TraceEvent {
        TraceEvent { ts, dur, lane, kind }
    }

    #[test]
    fn nested_spans_lower_to_balanced_pairs() {
        let trace = Trace {
            events: vec![
                ev(0, 100, 0, SpanKind::Epoch),
                ev(10, 20, 0, SpanKind::Superstep { pos: 1, block: 0, blocks: 1 }),
                ev(40, 20, 0, SpanKind::Superstep { pos: 2, block: 1, blocks: 1 }),
            ],
            lanes: vec![(0, "driver".into())],
            dropped: 0,
        };
        let evs = chrome_events(&trace, None);
        validate(&evs).unwrap();
        let b = evs.iter().filter(|e| e.ph == 'B').count();
        let e = evs.iter().filter(|e| e.ph == 'E').count();
        assert_eq!(b, 3);
        assert_eq!(b, e);
        let json = render(&evs);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"M\""), "lane metadata present");
        assert!(json.contains("driver"));
    }

    #[test]
    fn overlong_child_is_clipped_to_parent() {
        // Child claims to end after its parent — lowering must clip.
        let trace = Trace {
            events: vec![
                ev(0, 50, 3, SpanKind::Epoch),
                ev(40, 100, 3, SpanKind::NodeBatch { node: 1, step: 2 }),
            ],
            lanes: vec![],
            dropped: 0,
        };
        let evs = chrome_events(&trace, None);
        validate(&evs).unwrap();
    }

    #[test]
    fn lanes_do_not_interfere() {
        let trace = Trace {
            events: vec![
                ev(0, 100, 0, SpanKind::Epoch),
                ev(5, 200, 1, SpanKind::NodeBatch { node: 0, step: 1 }),
            ],
            lanes: vec![],
            dropped: 0,
        };
        validate(&chrome_events(&trace, None)).unwrap();
    }

    #[test]
    fn validator_rejects_imbalance() {
        let bad = vec![ChromeEvent {
            ph: 'B',
            name: "x".into(),
            cat: "engine",
            ts_ns: 0,
            lane: 0,
            args: vec![],
        }];
        assert!(validate(&bad).is_err());
    }

    #[test]
    fn escape_handles_quotes_and_control() {
        assert_eq!(escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape("x\ny"), "x\\u000ay");
    }
}
