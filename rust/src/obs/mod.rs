//! `obs::` — low-overhead tracing for the engine and the job service:
//! per-epoch / per-superstep / per-operator **span timelines** with
//! Chrome-trace export and a human breakdown report.
//!
//! The paper's core claim is quantitative (per-iteration-step overhead
//! orders of magnitude below a job launch per step), so time must be
//! attributable to the places where that overhead would live: control
//! path appends (supersteps), operator batch work, driver dispatch and
//! teardown, and — under `serve::` — queue wait, compile, binding, and
//! the epoch itself. This module supplies the event model and the
//! machinery; `exec::` and `serve::` are instrumented against it.
//!
//! ## Design
//!
//! * **Disabled means free.** Tracing hangs off
//!   [`crate::exec::ExecConfig::trace`] as an `Option<Arc<Tracer>>`.
//!   With `None` (the default unless `LABY_TRACE=1`), every
//!   instrumentation site is a branch on an `Option` that is never
//!   taken — no clock reads, no allocation, no atomics. A present but
//!   [`Tracer::set_enabled`]-off tracer is checked **once per epoch**
//!   (a load of an `Arc<AtomicBool>`), after which the disabled epoch
//!   runs the same no-op branches.
//! * **Per-worker ring buffers.** Each traced thread records into its
//!   own [`SpanBuf`] — a fixed-capacity ring owned by that thread, so
//!   the hot path is an unsynchronized `Vec` write (oldest events are
//!   overwritten on overflow and counted as dropped). Buffers are
//!   absorbed into the tracer's shared sink **once per epoch**, the
//!   only locking the data plane ever pays.
//! * **Complete spans, not B/E pairs.** Events carry `(ts, dur)`; the
//!   Chrome exporter ([`chrome`]) derives balanced begin/end pairs at
//!   export time, which keeps the ring robust to overflow (dropping a
//!   complete span can never unbalance the trace).
//!
//! Consume a trace with [`Tracer::take`], render it with
//! [`report::render_breakdown`] (the `labyrinth trace` CLI) or
//! [`chrome::render`] (Perfetto / `chrome://tracing` JSON). See
//! `docs/observability.md` for the event model and overhead budget.

pub mod chrome;
pub mod report;

use crate::frontend::BlockId;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread ring capacity (events). At one event per data
/// batch this covers ~64k batches per worker per epoch before the ring
/// starts overwriting its oldest events.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

/// What a span measures. Node/step payloads are compact copies (ids,
/// not names); names are resolved at export time against the graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One whole engine epoch (driver lane): dispatch → teardown done.
    Epoch,
    /// Worker-channel setup + epoch dispatch onto the pool (driver).
    Dispatch,
    /// Epoch teardown: shutdown broadcast → all worker done-reports.
    Drain,
    /// One control-path append: positions `pos .. pos + blocks` of the
    /// execution path, lasting until the next append (or epoch end).
    /// Every appended position is one superstep; appends batch the
    /// blocks of one §6.3.1 decision.
    Superstep { pos: u32, block: BlockId, blocks: u32 },
    /// One `Transformation::push_in_batch` (or legacy element loop) on
    /// a worker: node self-time at batch granularity. `step` is the
    /// output bag id (path-prefix length).
    NodeBatch { node: u32, step: u32 },
    /// `close_in_bag` / `close_out_bag` work (build/reduce emission).
    NodeClose { node: u32, step: u32 },
    /// Source generation (`Transformation::generate`).
    Generate { node: u32, step: u32 },
    /// recovery: a superstep-boundary checkpoint cut (driver lane) —
    /// decision chain withheld at path length `pos`, prefix drained to
    /// quiescence, every worker snapshotted. Span covers withhold →
    /// checkpoint stored.
    Checkpoint { pos: u32 },
    /// recovery: instant marker on a resumed epoch — the driver
    /// re-seeded a checkpointed prefix of length `pos` instead of
    /// re-running it.
    Recover { pos: u32 },
    /// serve: admission-queue wait (submit → lane pickup).
    Queue { job: u64 },
    /// serve: plan-template resolution (compile on miss, ~0 on hit).
    Compile { job: u64 },
    /// serve: request binding — registry overlay + preamble signature.
    Bind { job: u64 },
    /// serve: the job's engine epoch on the lane's warm pool.
    JobRun { job: u64 },
    /// serve: whole request, submit → reply.
    Request { job: u64 },
    /// serve: instant marker — an elastic lane resized its worker pool
    /// between epochs (`from` → `to` resident threads).
    PoolResize { lane: u32, from: u32, to: u32 },
}

/// One recorded span: `dur == 0` marks an instant event.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Nanoseconds since the tracer's origin.
    pub ts: u64,
    /// Span length in nanoseconds.
    pub dur: u64,
    /// Timeline lane (exported as the Chrome-trace `tid`). Allocated
    /// per epoch per thread via [`Tracer::lane`], so concurrent epochs
    /// never interleave on one lane.
    pub lane: u32,
    /// What was measured.
    pub kind: SpanKind,
}

/// A drained trace: events (sorted by start time), lane names, and how
/// many events the ring buffers overwrote.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// All absorbed events, sorted by `(ts, lane)`.
    pub events: Vec<TraceEvent>,
    /// `(lane, name)` pairs in allocation order.
    pub lanes: Vec<(u32, String)>,
    /// Events lost to ring overwrites (oldest-first per ring).
    pub dropped: u64,
}

impl Trace {
    /// Events of one kind-predicate, in time order.
    pub fn spans(&self, mut pred: impl FnMut(&SpanKind) -> bool) -> Vec<TraceEvent> {
        self.events.iter().filter(|e| pred(&e.kind)).copied().collect()
    }
}

/// The shared tracing sink: an enable gate, a time origin, lane
/// allocation, and the per-epoch absorption target for [`SpanBuf`]s.
#[derive(Debug)]
pub struct Tracer {
    enabled: AtomicBool,
    t0: Instant,
    capacity: usize,
    next_lane: AtomicU32,
    sink: Mutex<Vec<TraceEvent>>,
    lane_names: Mutex<Vec<(u32, String)>>,
    dropped: AtomicU64,
}

impl Tracer {
    /// Create a tracer with the default ring capacity.
    pub fn new(enabled: bool) -> Tracer {
        Tracer::with_capacity(enabled, DEFAULT_RING_CAPACITY)
    }

    /// Create a tracer whose per-thread rings hold `capacity` events.
    pub fn with_capacity(enabled: bool, capacity: usize) -> Tracer {
        Tracer {
            enabled: AtomicBool::new(enabled),
            t0: Instant::now(),
            capacity: capacity.max(16),
            next_lane: AtomicU32::new(0),
            sink: Mutex::new(Vec::new()),
            lane_names: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// Is tracing on? Checked once per epoch by the engine; instrument
    /// sites gated off a dead tracer cost one atomic load per epoch.
    pub fn on(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Flip the gate (effective at the next epoch boundary).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Nanoseconds since the tracer's origin.
    pub fn now_ns(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    /// Allocate a named timeline lane (unique per tracer lifetime —
    /// concurrent epochs get disjoint lanes).
    pub fn lane(&self, name: &str) -> u32 {
        let id = self.next_lane.fetch_add(1, Ordering::Relaxed);
        self.lane_names.lock().unwrap().push((id, name.to_string()));
        id
    }

    /// Create the thread-owned ring buffer for `lane`.
    pub fn local(&self, lane: u32) -> SpanBuf {
        SpanBuf {
            lane,
            t0: self.t0,
            cap: self.capacity,
            buf: Vec::with_capacity(self.capacity.min(1024)),
            head: 0,
            dropped: 0,
        }
    }

    /// Absorb a ring into the shared sink (one lock per epoch per
    /// thread; oldest-first when the ring wrapped).
    pub fn absorb(&self, buf: SpanBuf) {
        self.dropped.fetch_add(buf.dropped, Ordering::Relaxed);
        let mut sink = self.sink.lock().unwrap();
        let SpanBuf { buf, head, .. } = buf;
        if head > 0 {
            // Wrapped: buf[head..] is oldest.
            sink.extend_from_slice(&buf[head..]);
            sink.extend_from_slice(&buf[..head]);
        } else {
            sink.extend(buf);
        }
    }

    /// Record one span directly into the shared sink (locks; for
    /// low-rate control-plane spans such as the serve lifecycle, never
    /// the data plane).
    pub fn push(&self, lane: u32, kind: SpanKind, ts: u64, dur: u64) {
        self.sink.lock().unwrap().push(TraceEvent { ts, dur, lane, kind });
    }

    /// Drain everything recorded so far into a [`Trace`] (events
    /// sorted, names snapshotted, counters reset for reuse).
    pub fn take(&self) -> Trace {
        let mut events = std::mem::take(&mut *self.sink.lock().unwrap());
        events.sort_by_key(|e| (e.ts, e.lane));
        Trace {
            events,
            lanes: self.lane_names.lock().unwrap().clone(),
            dropped: self.dropped.swap(0, Ordering::Relaxed),
        }
    }

    /// Events lost to ring overwrites since the last [`Tracer::take`].
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// The process-global tracer behind `LABY_TRACE=1` (read once, like
/// `LABY_BATCH`): `Some` and enabled when set, `None` otherwise.
/// [`crate::exec::ExecConfig::default`] and
/// [`crate::serve::ServeConfig::default`] attach it.
pub fn default_tracer() -> Option<Arc<Tracer>> {
    static T: OnceLock<Option<Arc<Tracer>>> = OnceLock::new();
    T.get_or_init(|| {
        (std::env::var("LABY_TRACE").ok().as_deref() == Some("1"))
            .then(|| Arc::new(Tracer::new(true)))
    })
    .clone()
}

/// A thread-owned span ring: unsynchronized writes, fixed capacity,
/// oldest events overwritten on overflow. Created by [`Tracer::local`]
/// and given back with [`Tracer::absorb`] at the epoch boundary.
#[derive(Debug)]
pub struct SpanBuf {
    lane: u32,
    t0: Instant,
    cap: usize,
    buf: Vec<TraceEvent>,
    /// Next overwrite position once the ring is full.
    head: usize,
    dropped: u64,
}

impl SpanBuf {
    /// Nanoseconds since the owning tracer's origin (span start marks).
    pub fn now(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    /// The lane this ring records on.
    pub fn lane(&self) -> u32 {
        self.lane
    }

    /// Close a span opened at `start` (from [`SpanBuf::now`]); returns
    /// its duration in nanoseconds so callers can also accumulate it
    /// (per-node self-time).
    pub fn record(&mut self, kind: SpanKind, start: u64) -> u64 {
        let now = self.now();
        let dur = now.saturating_sub(start);
        self.push(TraceEvent { ts: start, dur, lane: self.lane, kind });
        dur
    }

    /// Record a complete span with explicit bounds.
    pub fn record_span(&mut self, kind: SpanKind, ts: u64, dur: u64) {
        self.push(TraceEvent { ts, dur, lane: self.lane, kind });
    }

    /// Record an instant event (zero duration).
    pub fn instant(&mut self, kind: SpanKind) {
        let now = self.now();
        self.push(TraceEvent { ts: now, dur: 0, lane: self.lane, kind });
    }

    fn push(&mut self, e: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            // Ring overwrite: the oldest event gives way.
            self.buf[self.head] = e;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// No events recorded yet?
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let t = Tracer::with_capacity(true, 16);
        let lane = t.lane("w");
        let mut buf = t.local(lane);
        for i in 0..20u64 {
            buf.record_span(SpanKind::NodeBatch { node: 0, step: i as u32 }, i, 1);
        }
        assert_eq!(buf.len(), 16);
        t.absorb(buf);
        let trace = t.take();
        assert_eq!(trace.dropped, 4);
        assert_eq!(trace.events.len(), 16);
        // Oldest four (ts 0..3) were overwritten; order is by ts.
        assert_eq!(trace.events.first().unwrap().ts, 4);
        assert_eq!(trace.events.last().unwrap().ts, 19);
    }

    #[test]
    fn lanes_are_unique_and_named() {
        let t = Tracer::new(true);
        let a = t.lane("driver");
        let b = t.lane("worker 0");
        assert_ne!(a, b);
        let trace = t.take();
        assert_eq!(trace.lanes.len(), 2);
        assert!(trace.lanes.iter().any(|(id, n)| *id == a && n == "driver"));
    }

    #[test]
    fn take_resets_the_sink() {
        let t = Tracer::new(true);
        let lane = t.lane("x");
        t.push(lane, SpanKind::Epoch, 0, 10);
        assert_eq!(t.take().events.len(), 1);
        assert!(t.take().events.is_empty());
    }

    #[test]
    fn disabled_gate_reads_false() {
        let t = Tracer::new(false);
        assert!(!t.on());
        t.set_enabled(true);
        assert!(t.on());
    }
}
