//! The human-readable side of a trace: the per-superstep and
//! per-operator time breakdown printed by `labyrinth trace`.

use super::{SpanKind, Trace};
use crate::dataflow::DataflowGraph;
use crate::exec::RunOutput;
use crate::util::{fmt_duration, pad};
use std::fmt::Write as _;
use std::time::Duration;

/// Cap on individually listed superstep rows (long loops aggregate).
const MAX_STEP_ROWS: usize = 24;

/// Render the per-superstep / per-operator breakdown of one epoch.
///
/// The superstep table attributes wall time to control-path appends
/// (each row is one §6.3.1 decision's chain of appended blocks); the
/// operator table attributes measured **self-time** (batch + close +
/// generate spans) to logical nodes, alongside the row/bag counts the
/// engine already collects. Self-time is per-thread CPU-side wall time,
/// so with W workers the column can sum to up to W× the epoch wall.
pub fn render_breakdown(trace: &Trace, graph: &DataflowGraph, out: &RunOutput) -> String {
    let mut s = String::new();
    let epoch = trace
        .events
        .iter()
        .filter(|e| e.kind == SpanKind::Epoch)
        .map(|e| e.dur)
        .max()
        .unwrap_or_else(|| out.elapsed.as_nanos() as u64)
        .max(1);

    let _ = writeln!(
        s,
        "== trace: {} control-flow steps, epoch {} ({} events{}) ==",
        out.path_len,
        fmt_duration(Duration::from_nanos(epoch)),
        trace.events.len(),
        if trace.dropped > 0 {
            format!(", {} dropped", trace.dropped)
        } else {
            String::new()
        },
    );

    // --- per-superstep ------------------------------------------------
    let steps = trace.spans(|k| matches!(k, SpanKind::Superstep { .. }));
    if !steps.is_empty() {
        let _ = writeln!(s, "\nper-superstep (one row per control-path append):");
        let _ = writeln!(s, "  {} {} {}", pad("steps", 12), pad("block", 8), pad("wall", 12));
        let shown = steps.len().min(MAX_STEP_ROWS);
        for e in &steps[..shown] {
            let SpanKind::Superstep { pos, block, blocks } = e.kind else { continue };
            let label = if blocks > 1 {
                format!("{pos}..{}", pos + blocks - 1)
            } else {
                format!("{pos}")
            };
            let _ = writeln!(
                s,
                "  {} {} {}",
                pad(&label, 12),
                pad(&format!("bb{block}"), 8),
                pad(&fmt_duration(Duration::from_nanos(e.dur)), 12),
            );
        }
        if steps.len() > shown {
            let rest: u64 = steps[shown..].iter().map(|e| e.dur).sum();
            let _ = writeln!(
                s,
                "  {} {} {}",
                pad(&format!("(+{} more)", steps.len() - shown), 12),
                pad("", 8),
                pad(&fmt_duration(Duration::from_nanos(rest)), 12),
            );
        }
    }

    // --- per-operator -------------------------------------------------
    #[derive(Default, Clone)]
    struct NodeAgg {
        self_ns: u64,
        batches: u64,
    }
    let mut agg: Vec<NodeAgg> = vec![NodeAgg::default(); graph.num_nodes()];
    for e in &trace.events {
        let node = match e.kind {
            SpanKind::NodeBatch { node, .. }
            | SpanKind::NodeClose { node, .. }
            | SpanKind::Generate { node, .. } => node as usize,
            _ => continue,
        };
        if let Some(a) = agg.get_mut(node) {
            a.self_ns += e.dur;
            a.batches += 1;
        }
    }
    let mut order: Vec<usize> = (0..graph.num_nodes()).collect();
    order.sort_by_key(|&n| std::cmp::Reverse(agg[n].self_ns));

    let _ = writeln!(s, "\nper-operator (self-time from traced batch/close/generate spans):");
    let _ = writeln!(
        s,
        "  {} {} {} {} {} {}",
        pad("node", 22),
        pad("bags", 7),
        pad("rows", 10),
        pad("spans", 7),
        pad("self", 12),
        pad("% epoch", 8),
    );
    for n in order {
        let a = &agg[n];
        let rows = out.node_rows.get(n);
        if a.self_ns == 0 && rows.map_or(true, |r| r.rows == 0 && r.bags == 0) {
            continue;
        }
        let name = format!("{} {}", graph.nodes[n].name, graph.nodes[n].op.mnemonic());
        let name = if name.len() > 22 { name[..22].to_string() } else { name };
        let _ = writeln!(
            s,
            "  {} {} {} {} {} {}",
            pad(&name, 22),
            pad(&rows.map_or(0, |r| r.bags).to_string(), 7),
            pad(&rows.map_or(0, |r| r.rows).to_string(), 10),
            pad(&a.batches.to_string(), 7),
            pad(&fmt_duration(Duration::from_nanos(a.self_ns)), 12),
            pad(&format!("{:.1}%", a.self_ns as f64 * 100.0 / epoch as f64), 8),
        );
    }

    // --- driver/control accounting ------------------------------------
    let dispatch: u64 =
        trace.spans(|k| matches!(k, SpanKind::Dispatch)).iter().map(|e| e.dur).sum();
    let drain: u64 = trace.spans(|k| matches!(k, SpanKind::Drain)).iter().map(|e| e.dur).sum();
    let self_total: u64 = agg.iter().map(|a| a.self_ns).sum();
    let _ = writeln!(
        s,
        "\ncontrol plane: dispatch {}, drain {}; operator self-time total {} \
         (threads may overlap the epoch wall)",
        fmt_duration(Duration::from_nanos(dispatch)),
        fmt_duration(Duration::from_nanos(drain)),
        fmt_duration(Duration::from_nanos(self_total)),
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{run, ExecConfig};
    use crate::obs::Tracer;
    use std::sync::Arc;

    #[test]
    fn breakdown_renders_steps_and_operators() {
        let g = crate::compile_source(
            "d = 1; s = bag(); while (d <= 3) { s = bag(1, 2, 3).map(|x| x * d); d = d + 1; } \
             collect(s, \"s\");",
        )
        .unwrap();
        let tracer = Arc::new(Tracer::new(true));
        let out = run(
            &g,
            &ExecConfig { workers: 2, trace: Some(tracer.clone()), ..Default::default() },
        )
        .unwrap();
        let trace = tracer.take();
        let rep = render_breakdown(&trace, &g, &out);
        assert!(rep.contains("per-superstep"), "{rep}");
        assert!(rep.contains("per-operator"), "{rep}");
        assert!(rep.contains("bb"), "{rep}");
        assert!(rep.contains("% epoch"), "{rep}");
    }
}
