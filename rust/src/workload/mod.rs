//! Synthetic workload generators for the paper's evaluation programs
//! (DESIGN.md §2: the original 19 GB page-visit logs and 26-node cluster
//! are unavailable; these generators produce scaled-down datasets with the
//! same shape) plus the named-source registry that feeds benches without
//! disk I/O.

pub mod registry;

use crate::util::rng::Rng;
use crate::value::Value;

/// Parameters for the Visit Count workload (§3.1 / §9.2.1).
#[derive(Clone, Debug)]
pub struct VisitCountWorkload {
    /// Number of days (the paper uses 100 in §9.2.1).
    pub days: usize,
    /// Page-visit log entries per day.
    pub visits_per_day: usize,
    /// Number of distinct pages.
    pub num_pages: usize,
    /// Zipf skew of page popularity.
    pub skew: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for VisitCountWorkload {
    fn default() -> Self {
        VisitCountWorkload {
            days: 10,
            visits_per_day: 10_000,
            num_pages: 1_000,
            skew: 1.05,
            seed: 42,
        }
    }
}

impl VisitCountWorkload {
    /// Generate the visit log for one day: a bag of `I64` page ids.
    pub fn day_visits(&self, day: usize) -> Vec<Value> {
        let mut rng = Rng::new(self.seed ^ (day as u64).wrapping_mul(0x9E37_79B9));
        (0..self.visits_per_day)
            .map(|_| Value::I64(rng.gen_zipf(self.num_pages as u64, self.skew) as i64))
            .collect()
    }

    /// Generate the page-attributes table: `Pair(pageId, typeId)` with
    /// `typeId` in `[0, 4)` (the paper filters one page type, §3.1).
    pub fn page_attributes(&self) -> Vec<Value> {
        let mut rng = Rng::new(self.seed ^ 0xA77);
        (0..self.num_pages)
            .map(|p| Value::pair(Value::I64(p as i64), Value::I64(rng.gen_i64(0, 4))))
            .collect()
    }

    /// Register all day logs and the attribute table as named sources:
    /// `"{prefix}visits{day}"` (day is 1-based) and `"{prefix}attrs"`.
    pub fn register(&self, prefix: &str) {
        let reg = registry::global();
        for day in 1..=self.days {
            reg.put(format!("{prefix}visits{day}"), self.day_visits(day));
        }
        reg.put(format!("{prefix}attrs"), self.page_attributes());
    }

    /// Write the logs as files under `dir` (one id per line) for the
    /// end-to-end `readFile` example.
    pub fn write_files(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        for day in 1..=self.days {
            let mut s = String::new();
            for v in self.day_visits(day) {
                s.push_str(&format!("{}\n", v.as_i64()));
            }
            std::fs::write(dir.join(format!("pageVisitLog{day}")), s)?;
        }
        let mut s = String::new();
        for v in self.page_attributes() {
            if let Value::Pair(p) = v {
                s.push_str(&format!("{} {}\n", p.0, p.1));
            }
        }
        std::fs::write(dir.join("pageAttributes"), s)?;
        Ok(())
    }
}

/// Parameters for the PageRank workload (§9.2.2): per-day page-transition
/// graphs.
#[derive(Clone, Debug)]
pub struct PageRankWorkload {
    /// Number of days (outer loop).
    pub days: usize,
    /// Pages (graph vertices).
    pub num_pages: usize,
    /// Transitions (edges) per day.
    pub edges_per_day: usize,
    /// Zipf skew of transition targets.
    pub skew: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for PageRankWorkload {
    fn default() -> Self {
        PageRankWorkload { days: 3, num_pages: 500, edges_per_day: 5_000, skew: 1.0, seed: 7 }
    }
}

impl PageRankWorkload {
    /// Generate one day's transition bag: `Pair(src, dst)`.
    pub fn day_edges(&self, day: usize) -> Vec<Value> {
        let mut rng = Rng::new(self.seed ^ (day as u64).wrapping_mul(0xDEAD_BEEF));
        (0..self.edges_per_day)
            .map(|_| {
                let s = rng.gen_range(self.num_pages as u64) as i64;
                let d = rng.gen_zipf(self.num_pages as u64, self.skew) as i64;
                Value::pair(Value::I64(s), Value::I64(d))
            })
            .collect()
    }

    /// Register per-day edge bags as `"{prefix}edges{day}"` (1-based).
    pub fn register(&self, prefix: &str) {
        let reg = registry::global();
        for day in 1..=self.days {
            reg.put(format!("{prefix}edges{day}"), self.day_edges(day));
        }
    }
}

/// Reference single-threaded PageRank (power iteration with damping 0.85)
/// over an edge list — the oracle for kernel and dataflow validation.
pub fn pagerank_reference(edges: &[(usize, usize)], n: usize, iters: usize) -> Vec<f64> {
    let damping = 0.85;
    let mut out_deg = vec![0usize; n];
    for &(s, _) in edges {
        out_deg[s] += 1;
    }
    let mut rank = vec![1.0 / n as f64; n];
    for _ in 0..iters {
        let mut next = vec![(1.0 - damping) / n as f64; n];
        let mut dangling = 0.0;
        for (s, &d) in out_deg.iter().enumerate() {
            if d == 0 {
                dangling += rank[s];
            }
        }
        for v in next.iter_mut() {
            *v += damping * dangling / n as f64;
        }
        for &(s, d) in edges {
            next[d] += damping * rank[s] / out_deg[s] as f64;
        }
        rank = next;
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visit_logs_are_deterministic_and_in_range() {
        let w = VisitCountWorkload { days: 2, visits_per_day: 100, num_pages: 10, ..Default::default() };
        let a = w.day_visits(1);
        let b = w.day_visits(1);
        assert_eq!(a, b);
        assert_ne!(a, w.day_visits(2));
        for v in &a {
            assert!((0..10).contains(&v.as_i64()));
        }
    }

    #[test]
    fn attributes_cover_every_page_once() {
        let w = VisitCountWorkload { num_pages: 50, ..Default::default() };
        let attrs = w.page_attributes();
        assert_eq!(attrs.len(), 50);
        let mut pages: Vec<i64> = attrs.iter().map(|v| v.key().as_i64()).collect();
        pages.sort();
        assert_eq!(pages, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn register_names_resolvable() {
        let w = VisitCountWorkload { days: 2, visits_per_day: 10, ..Default::default() };
        w.register("t_");
        let reg = registry::global();
        assert!(reg.get("t_visits1").is_some());
        assert!(reg.get("t_visits2").is_some());
        assert!(reg.get("t_attrs").is_some());
        assert!(reg.get("t_visits3").is_none());
    }

    #[test]
    fn pagerank_reference_sums_to_one() {
        let edges = vec![(0, 1), (1, 2), (2, 0), (0, 2)];
        let r = pagerank_reference(&edges, 3, 50);
        let sum: f64 = r.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "{sum}");
        // Node 2 has two in-edges; it should outrank node 1.
        assert!(r[2] > r[1]);
    }

    #[test]
    fn pagerank_handles_dangling_nodes() {
        let edges = vec![(0, 1)]; // node 1 dangling
        let r = pagerank_reference(&edges, 2, 100);
        let sum: f64 = r.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }
}
