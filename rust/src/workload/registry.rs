//! Named in-memory dataset registry backing `source("name")` /
//! `Rhs::NamedSource`. Shared by all executors so every implementation of
//! an experiment reads identical data.
//!
//! A registry can be stacked on top of a **parent** ([`Registry::overlay`]):
//! lookups fall through to the parent when the local map has no entry.
//! The `serve::` job service uses this for per-request parameter binding —
//! each request gets a throwaway overlay over the service's base registry,
//! so requests can supply their own datasets (and scalar parameters as
//! singleton datasets) without mutating global state or invalidating the
//! cached plan template.

use crate::value::Value;
use once_cell::sync::Lazy;
use rustc_hash::FxHashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

/// Thread-safe name → dataset map, optionally layered over a parent.
#[derive(Default)]
pub struct Registry {
    map: Mutex<FxHashMap<String, Arc<Vec<Value>>>>,
    parent: Option<Arc<Registry>>,
}

impl Registry {
    /// Create an empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Create an empty overlay whose lookups fall through to `parent`.
    pub fn overlay(parent: Arc<Registry>) -> Registry {
        Registry { map: Mutex::new(FxHashMap::default()), parent: Some(parent) }
    }

    /// Insert (or replace) a dataset.
    pub fn put(&self, name: impl Into<String>, items: Vec<Value>) {
        self.map.lock().unwrap().insert(name.into(), Arc::new(items));
    }

    /// Insert (or replace) an already-shared dataset without copying.
    pub fn put_shared(&self, name: impl Into<String>, items: Arc<Vec<Value>>) {
        self.map.lock().unwrap().insert(name.into(), items);
    }

    /// Fetch a dataset (local map first, then the parent chain).
    pub fn get(&self, name: &str) -> Option<Arc<Vec<Value>>> {
        if let Some(d) = self.map.lock().unwrap().get(name).cloned() {
            return Some(d);
        }
        self.parent.as_ref().and_then(|p| p.get(name))
    }

    /// Remove LOCAL datasets whose names start with `prefix` (bench
    /// cleanup). Parent entries are untouched.
    pub fn clear_prefix(&self, prefix: &str) {
        self.map.lock().unwrap().retain(|k, _| !k.starts_with(prefix));
    }

    /// Number of locally registered datasets (excludes the parent).
    pub fn local_len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Registry")
            .field("local", &self.local_len())
            .field("overlay", &self.parent.is_some())
            .finish()
    }
}

/// The process-global registry.
pub fn global() -> Arc<Registry> {
    static GLOBAL: Lazy<Arc<Registry>> = Lazy::new(|| Arc::new(Registry::new()));
    GLOBAL.clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let r = Registry::new();
        r.put("a", vec![Value::I64(1)]);
        assert_eq!(r.get("a").unwrap().len(), 1);
        assert!(r.get("b").is_none());
    }

    #[test]
    fn clear_prefix_scopes_cleanup() {
        let r = Registry::new();
        r.put("x_1", vec![]);
        r.put("x_2", vec![]);
        r.put("y_1", vec![]);
        r.clear_prefix("x_");
        assert!(r.get("x_1").is_none());
        assert!(r.get("y_1").is_some());
    }

    #[test]
    fn global_is_shared() {
        global().put("registry_shared_test", vec![Value::I64(9)]);
        assert!(global().get("registry_shared_test").is_some());
    }

    #[test]
    fn overlay_shadows_and_falls_through() {
        let base = Arc::new(Registry::new());
        base.put("shared", vec![Value::I64(1)]);
        base.put("shadowed", vec![Value::I64(2)]);
        let ov = Registry::overlay(base.clone());
        ov.put("shadowed", vec![Value::I64(20), Value::I64(21)]);
        ov.put("own", vec![Value::I64(3)]);
        // Fall-through, shadowing, and locality.
        assert_eq!(ov.get("shared").unwrap().len(), 1);
        assert_eq!(ov.get("shadowed").unwrap().len(), 2);
        assert_eq!(ov.get("own").unwrap().len(), 1);
        assert!(base.get("own").is_none(), "overlay writes never leak to the parent");
        assert_eq!(base.get("shadowed").unwrap().len(), 1);
    }

    #[test]
    fn put_shared_avoids_copies() {
        let data = Arc::new(vec![Value::I64(7)]);
        let r = Registry::new();
        r.put_shared("s", data.clone());
        assert!(Arc::ptr_eq(&r.get("s").unwrap(), &data));
    }
}
