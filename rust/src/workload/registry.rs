//! Named in-memory dataset registry backing `source("name")` /
//! `Rhs::NamedSource`. Shared by all executors so every implementation of
//! an experiment reads identical data.

use crate::value::Value;
use once_cell::sync::Lazy;
use rustc_hash::FxHashMap;
use std::sync::{Arc, Mutex};

/// Thread-safe name → dataset map.
#[derive(Default)]
pub struct Registry {
    map: Mutex<FxHashMap<String, Arc<Vec<Value>>>>,
}

impl Registry {
    /// Create an empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Insert (or replace) a dataset.
    pub fn put(&self, name: impl Into<String>, items: Vec<Value>) {
        self.map.lock().unwrap().insert(name.into(), Arc::new(items));
    }

    /// Fetch a dataset.
    pub fn get(&self, name: &str) -> Option<Arc<Vec<Value>>> {
        self.map.lock().unwrap().get(name).cloned()
    }

    /// Remove datasets whose names start with `prefix` (bench cleanup).
    pub fn clear_prefix(&self, prefix: &str) {
        self.map.lock().unwrap().retain(|k, _| !k.starts_with(prefix));
    }
}

/// The process-global registry.
pub fn global() -> Arc<Registry> {
    static GLOBAL: Lazy<Arc<Registry>> = Lazy::new(|| Arc::new(Registry::new()));
    GLOBAL.clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let r = Registry::new();
        r.put("a", vec![Value::I64(1)]);
        assert_eq!(r.get("a").unwrap().len(), 1);
        assert!(r.get("b").is_none());
    }

    #[test]
    fn clear_prefix_scopes_cleanup() {
        let r = Registry::new();
        r.put("x_1", vec![]);
        r.put("x_2", vec![]);
        r.put("y_1", vec![]);
        r.clear_prefix("x_");
        assert!(r.get("x_1").is_none());
        assert!(r.get("y_1").is_some());
    }

    #[test]
    fn global_is_shared() {
        global().put("registry_shared_test", vec![Value::I64(9)]);
        assert!(global().get("registry_shared_test").is_some());
    }
}
