//! Element-wise and structural transformations: map, filter, flatMap,
//! union, cross, Φ, and the pass-through used by collect sinks.
//!
//! The element-wise operators override `push_in_batch` with tight loops
//! staging into a reusable buffer — one collector call per batch instead
//! of per element.

use super::{Collector, Transformation};
use crate::bag::ColumnBatch;
use crate::frontend::{Udf1, UdfN};
use crate::opt::types::TypedUdf1;
use crate::value::Value;

/// `map`: apply a UDF to every element (fully pipelined).
pub struct MapT {
    udf: Udf1,
    /// Monomorphic columnar kernel ([`crate::opt::types::compile_udf1`])
    /// installed by `ops::make` when the inferred input type and the
    /// lambda body allow. Advisory: every batch re-verifies its layout
    /// during decode, falling back to the dynamic loop on mismatch.
    typed: Option<TypedUdf1>,
    /// Staging buffer reused across batches.
    buf: Vec<Value>,
}

impl MapT {
    /// Create from a UDF (dynamic path only).
    pub fn new(udf: Udf1) -> MapT {
        MapT { udf, typed: None, buf: Vec::new() }
    }

    /// Create with an optional compiled columnar kernel (engine path,
    /// gated by `opt.columnar`).
    pub fn with_typed(udf: Udf1, typed: Option<TypedUdf1>) -> MapT {
        MapT { udf, typed, buf: Vec::new() }
    }
}

impl Transformation for MapT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        out.emit(self.udf.call(v));
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        if let Some(t) = &self.typed {
            if let Some(cols) = ColumnBatch::from_values(vs, t.input_type()) {
                if let Some(mapped) = t.map_batch(&cols) {
                    out.emit_columns(mapped);
                    return;
                }
            }
        }
        self.buf.reserve(vs.len());
        for v in vs {
            self.buf.push(self.udf.call(v));
        }
        out.emit_batch(&mut self.buf);
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
}

/// `filter`: keep elements whose predicate returns `Bool(true)`.
pub struct FilterT {
    udf: Udf1,
    /// Compiled columnar predicate; same advisory contract as
    /// [`MapT::typed`].
    typed: Option<TypedUdf1>,
    /// Staging buffer reused across batches.
    buf: Vec<Value>,
}

impl FilterT {
    /// Create from a predicate UDF (dynamic path only).
    pub fn new(udf: Udf1) -> FilterT {
        FilterT { udf, typed: None, buf: Vec::new() }
    }

    /// Create with an optional compiled columnar predicate (engine path,
    /// gated by `opt.columnar`).
    pub fn with_typed(udf: Udf1, typed: Option<TypedUdf1>) -> FilterT {
        FilterT { udf, typed, buf: Vec::new() }
    }
}

impl Transformation for FilterT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        if self.udf.call(v).as_bool() {
            out.emit(v.clone());
        }
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        if let Some(t) = &self.typed {
            if let Some(mut cols) = ColumnBatch::from_values(vs, t.input_type()) {
                if t.filter_batch(&mut cols).is_some() {
                    out.emit_columns(cols);
                    return;
                }
            }
        }
        for v in vs {
            if self.udf.call(v).as_bool() {
                self.buf.push(v.clone());
            }
        }
        out.emit_batch(&mut self.buf);
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
}

/// `flatMap`: one-to-many map (fully pipelined).
pub struct FlatMapT {
    udf: UdfN,
    /// Staging buffer reused across batches.
    buf: Vec<Value>,
}

impl FlatMapT {
    /// Create from an expansion UDF.
    pub fn new(udf: UdfN) -> FlatMapT {
        FlatMapT { udf, buf: Vec::new() }
    }
}

impl Transformation for FlatMapT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        for x in self.udf.call(v) {
            out.emit(x);
        }
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        for v in vs {
            self.buf.extend(self.udf.call(v));
        }
        out.emit_batch(&mut self.buf);
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
}

/// Clone a whole borrowed batch into a reusable staging buffer and hand
/// it to the collector in one call (the pass-through operators' batch
/// kernel; `buf` comes back empty with its allocation intact).
fn pass_batch(buf: &mut Vec<Value>, vs: &[Value], out: &mut dyn Collector) {
    buf.extend_from_slice(vs);
    out.emit_batch(buf);
}

/// `union`: multiset union — pass through both inputs.
#[derive(Default)]
pub struct UnionT {
    /// Staging buffer reused across batches.
    buf: Vec<Value>,
}

impl Transformation for UnionT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        out.emit(v.clone());
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        pass_batch(&mut self.buf, vs, out);
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
}

/// Φ-node: for each output bag the runtime feeds exactly one input (the
/// one selected by §6.3.3's longest-prefix rule); elements pass through.
#[derive(Default)]
pub struct PhiT {
    /// Staging buffer reused across batches.
    buf: Vec<Value>,
}

impl Transformation for PhiT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        out.emit(v.clone());
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        pass_batch(&mut self.buf, vs, out);
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
}

/// Pass-through for `collect` sinks (the engine captures the emitted bag
/// and forwards it to the driver).
#[derive(Default)]
pub struct PassThroughT {
    /// Staging buffer reused across batches.
    buf: Vec<Value>,
}

impl Transformation for PassThroughT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        out.emit(v.clone());
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        pass_batch(&mut self.buf, vs, out);
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
}

/// `cross`: Cartesian product, emitting `Pair(left, right)`. Primarily the
/// lifted form of binary scalar functions (§5.2), where both inputs are
/// one-element bags. The left input is retained across output bags when
/// loop-invariant (`keeps_input_state`).
pub struct CrossT {
    left: Vec<Value>,
    right: Vec<Value>,
    left_closed: bool,
}

impl CrossT {
    /// Create an empty cross.
    pub fn new() -> CrossT {
        CrossT { left: Vec::new(), right: Vec::new(), left_closed: false }
    }
}

impl Default for CrossT {
    fn default() -> Self {
        Self::new()
    }
}

impl Transformation for CrossT {
    fn open_out_bag(&mut self) {
        self.right.clear();
    }
    fn push_in_element(&mut self, input: usize, v: &Value, out: &mut dyn Collector) {
        if input == 0 {
            self.left.push(v.clone());
        } else if self.left_closed {
            // Left side complete: stream right elements against it.
            for l in &self.left {
                out.emit(Value::pair(l.clone(), v.clone()));
            }
        } else {
            self.right.push(v.clone());
        }
    }
    fn close_in_bag(&mut self, input: usize, out: &mut dyn Collector) {
        if input == 0 {
            self.left_closed = true;
            for r in std::mem::take(&mut self.right) {
                for l in &self.left {
                    out.emit(Value::pair(l.clone(), r.clone()));
                }
            }
        }
    }
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
    fn drop_state(&mut self, input: usize) {
        if input == 0 {
            self.left.clear();
            self.left_closed = false;
        }
    }
    fn keeps_input_state(&self, input: usize) -> bool {
        input == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::Udf1;
    use crate::ops::run_once;

    fn i(v: i64) -> Value {
        Value::I64(v)
    }

    #[test]
    fn map_applies_udf() {
        let mut t = MapT::new(Udf1::new("x+1", |v: &Value| i(v.as_i64() + 1)));
        let out = run_once(&mut t, &[&[i(1), i(2)]]);
        assert_eq!(out, vec![i(2), i(3)]);
    }

    #[test]
    fn filter_keeps_matching() {
        let mut t = FilterT::new(Udf1::new("even", |v: &Value| {
            Value::Bool(v.as_i64() % 2 == 0)
        }));
        let out = run_once(&mut t, &[&[i(1), i(2), i(3), i(4)]]);
        assert_eq!(out, vec![i(2), i(4)]);
    }

    #[test]
    fn flat_map_expands() {
        let mut t = FlatMapT::new(crate::frontend::UdfN::new("dup", |v: &Value| {
            vec![v.clone(), v.clone()]
        }));
        let out = run_once(&mut t, &[&[i(7)]]);
        assert_eq!(out, vec![i(7), i(7)]);
    }

    #[test]
    fn union_merges_inputs() {
        let mut t = UnionT::default();
        let out = run_once(&mut t, &[&[i(1)], &[i(2), i(3)]]);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn cross_emits_all_pairs() {
        let mut t = CrossT::new();
        let out = run_once(&mut t, &[&[i(1), i(2)], &[i(10)]]);
        assert_eq!(out.len(), 2);
        assert!(out.contains(&Value::pair(i(1), i(10))));
        assert!(out.contains(&Value::pair(i(2), i(10))));
    }

    #[test]
    fn cross_right_before_left_close_buffers() {
        // Right elements arriving before the left side closes must still
        // produce the full product.
        let mut t = CrossT::new();
        let mut out = crate::ops::VecCollector::default();
        t.open_out_bag();
        t.push_in_element(1, &i(10), &mut out);
        t.push_in_element(0, &i(1), &mut out);
        t.close_in_bag(0, &mut out);
        t.push_in_element(1, &i(20), &mut out);
        t.close_in_bag(1, &mut out);
        t.close_out_bag(&mut out);
        assert_eq!(out.items.len(), 2);
    }

    #[test]
    fn cross_reuses_left_until_drop_state() {
        let mut t = CrossT::new();
        let first = run_once(&mut t, &[&[i(5)], &[i(1)]]);
        assert_eq!(first, vec![Value::pair(i(5), i(1))]);
        // Second bag: left NOT re-fed (runtime contract for kept state).
        let mut out = crate::ops::VecCollector::default();
        t.open_out_bag();
        t.push_in_element(1, &i(2), &mut out);
        t.close_in_bag(1, &mut out);
        t.close_out_bag(&mut out);
        assert_eq!(out.items, vec![Value::pair(i(5), i(2))]);
        // After drop_state the left is gone.
        t.drop_state(0);
        let mut out2 = crate::ops::VecCollector::default();
        t.open_out_bag();
        t.push_in_element(1, &i(3), &mut out2);
        t.close_in_bag(1, &mut out2);
        t.close_out_bag(&mut out2);
        assert!(out2.items.is_empty());
    }

    #[test]
    fn phi_passes_through() {
        let mut t = PhiT::default();
        let out = run_once(&mut t, &[&[i(42)]]);
        assert_eq!(out, vec![i(42)]);
    }

    #[test]
    fn batch_kernels_agree_with_element_delivery() {
        // Whole-bag, chunked, and element-at-a-time delivery must produce
        // identical output bags (order included).
        let input: Vec<Value> = (0..23).map(i).collect();
        let make: [fn() -> Box<dyn crate::ops::Transformation>; 3] = [
            || Box::new(MapT::new(Udf1::new("x*3", |v: &Value| i(v.as_i64() * 3)))),
            || {
                Box::new(FilterT::new(Udf1::new("odd", |v: &Value| {
                    Value::Bool(v.as_i64() % 2 == 1)
                })))
            },
            || {
                Box::new(FlatMapT::new(crate::frontend::UdfN::new("dup", |v: &Value| {
                    vec![v.clone(), v.clone()]
                })))
            },
        ];
        for mk in make {
            // `run_once` IS element-at-a-time delivery — the batch
            // kernels must agree with it at every chunk size.
            let element = run_once(mk().as_mut(), &[&input]);
            for chunk in [1usize, 2, 7, 256] {
                let got = crate::ops::run_once_chunked(mk().as_mut(), &[&input], chunk);
                assert_eq!(got, element, "chunk={chunk}");
            }
        }
    }
}
