//! Accelerated operators: dataflow transformations whose compute is an
//! AOT-compiled XLA artifact (JAX + Pallas, lowered once at build time —
//! see `python/compile/`). Execution goes through the
//! [`crate::runtime::XlaService`] thread; the bag⇄tensor bridge is
//! described by [`crate::runtime::XlaCallSpec`].
//!
//! The `PageRankStep` bridge shows §7 state reuse on a *tensor* operator:
//! the loop-invariant edge bag is tensorized into the dense transition
//! matrix exactly once, cached device-side under a service cache key, and
//! reused across iteration steps.

use super::{Collector, Transformation};
use crate::bag::Bag;
use crate::runtime::bridge::{self, BridgeKind, DenseMatrix};
use crate::runtime::service::{fresh_cache_key, Operand, TensorData, XlaService};
use crate::runtime::XlaCallSpec;
use crate::value::Value;

/// Transformation that buffers its input bag(s) and runs the artifact at
/// close.
pub struct XlaCallT {
    spec: XlaCallSpec,
    inputs: Vec<Vec<Value>>,
    /// Service-side cache key of the tensorized loop-invariant input.
    matrix_key: Option<u64>,
}

impl XlaCallT {
    /// Create from a call spec (artifact compiles lazily on first use).
    pub fn new(spec: XlaCallSpec) -> XlaCallT {
        let arity = spec.arity();
        XlaCallT { spec, inputs: vec![Vec::new(); arity], matrix_key: None }
    }

    fn execute(&mut self, out: &mut dyn Collector) {
        let svc = XlaService::global();
        match self.spec.bridge.clone() {
            BridgeKind::HistogramI64 { capacity, bins } => {
                let ids = Bag::from_vec(std::mem::take(&mut self.inputs[0]));
                let mut counts = vec![0f32; bins];
                for chunk in bridge::ids_to_chunks(&ids, capacity).expect("ids") {
                    let res = svc
                        .execute(
                            &self.spec.artifact,
                            vec![Operand::Inline {
                                data: TensorData::I32(chunk),
                                dims: vec![capacity as i64],
                            }],
                        )
                        .unwrap_or_else(|e| panic!("histogram exec: {e}"));
                    for (c, x) in counts.iter_mut().zip(res) {
                        *c += x;
                    }
                }
                for v in bridge::counts_to_pairs(&counts) {
                    out.emit(v);
                }
            }
            BridgeKind::PageRankStep { n } => {
                let m_operand = match self.matrix_key {
                    Some(key) => Operand::Cached { key },
                    None => {
                        let edges = Bag::from_vec(std::mem::take(&mut self.inputs[0]));
                        let m = DenseMatrix::from_edges(&edges, n).expect("edges");
                        let key = fresh_cache_key();
                        self.matrix_key = Some(key);
                        Operand::CacheAndUse {
                            key,
                            data: TensorData::F32(m.data),
                            dims: vec![n as i64, n as i64],
                        }
                    }
                };
                let ranks = Bag::from_vec(std::mem::take(&mut self.inputs[1]));
                let r = bridge::ranks_to_vec(&ranks, n).expect("ranks");
                let res = svc
                    .execute(
                        &self.spec.artifact,
                        vec![
                            m_operand,
                            Operand::Inline { data: TensorData::F32(r), dims: vec![n as i64] },
                        ],
                    )
                    .unwrap_or_else(|e| panic!("pagerank exec: {e}"));
                for v in bridge::vec_to_ranks(&res) {
                    out.emit(v);
                }
            }
            BridgeKind::MapF64 { capacity } => {
                let items = std::mem::take(&mut self.inputs[0]);
                let mut idx = 0;
                while idx < items.len() {
                    let end = (idx + capacity).min(items.len());
                    let mut chunk = vec![0f32; capacity];
                    for (k, v) in items[idx..end].iter().enumerate() {
                        chunk[k] = v.as_f64() as f32;
                    }
                    let res = svc
                        .execute(
                            &self.spec.artifact,
                            vec![Operand::Inline {
                                data: TensorData::F32(chunk),
                                dims: vec![capacity as i64],
                            }],
                        )
                        .unwrap_or_else(|e| panic!("map exec: {e}"));
                    for x in &res[..end - idx] {
                        out.emit(Value::F64(*x as f64));
                    }
                    idx = end;
                }
            }
        }
    }
}

impl Drop for XlaCallT {
    fn drop(&mut self) {
        if let Some(key) = self.matrix_key {
            XlaService::global().drop_cached(key);
        }
    }
}

impl Transformation for XlaCallT {
    fn open_out_bag(&mut self) {
        for (i, buf) in self.inputs.iter_mut().enumerate() {
            // Keep the loop-invariant input 0 of PageRankStep.
            if !(i == 0 && matches!(self.spec.bridge, BridgeKind::PageRankStep { .. })) {
                buf.clear();
            }
        }
    }
    fn push_in_element(&mut self, input: usize, v: &Value, _out: &mut dyn Collector) {
        self.inputs[input].push(v.clone());
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, out: &mut dyn Collector) {
        self.execute(out);
    }
    fn drop_state(&mut self, input: usize) {
        if input == 0 && matches!(self.spec.bridge, BridgeKind::PageRankStep { .. }) {
            if let Some(key) = self.matrix_key.take() {
                XlaService::global().drop_cached(key);
            }
            self.inputs[0].clear();
        }
    }
    fn keeps_input_state(&self, input: usize) -> bool {
        input == 0 && matches!(self.spec.bridge, BridgeKind::PageRankStep { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_matches_bridge() {
        let t = XlaCallT::new(XlaCallSpec::pagerank_step(8));
        assert_eq!(t.inputs.len(), 2);
        assert!(t.keeps_input_state(0));
        assert!(!t.keeps_input_state(1));
        let t2 = XlaCallT::new(XlaCallSpec::histogram(8, 4));
        assert_eq!(t2.inputs.len(), 1);
        assert!(!t2.keeps_input_state(0));
    }

    // Execution tests live in rust/tests/runtime_artifacts.rs (they need
    // `make artifacts` to have produced the HLO files).
}
