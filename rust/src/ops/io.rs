//! Sources and sinks: literal bags, named in-memory sources, and file I/O.
//! Parallel sources partition their elements round-robin over the node's
//! physical instances.

use super::{Collector, MakeCtx, Transformation};
use crate::value::Value;
use std::io::{BufRead, Write};

/// Literal bag source: instance `i` of `n` emits elements `i, i+n, ...`.
pub struct BagLitT {
    items: Vec<Value>,
    inst: usize,
    insts: usize,
}

impl BagLitT {
    /// Create for one physical instance.
    pub fn new(items: Vec<Value>, ctx: &MakeCtx) -> BagLitT {
        BagLitT { items, inst: ctx.inst, insts: ctx.insts }
    }
}

impl Transformation for BagLitT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, _v: &Value, _out: &mut dyn Collector) {
        unreachable!("source has no inputs")
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
    fn generate(&mut self, out: &mut dyn Collector) {
        for (i, v) in self.items.iter().enumerate() {
            if i % self.insts == self.inst {
                out.emit(v.clone());
            }
        }
    }
}

/// Named in-memory source, resolved through the workload registry (used by
/// benches/examples to avoid disk I/O noise).
pub struct NamedSourceT {
    name: String,
    inst: usize,
    insts: usize,
    registry: std::sync::Arc<crate::workload::registry::Registry>,
}

impl NamedSourceT {
    /// Create for one physical instance.
    pub fn new(name: String, ctx: &MakeCtx) -> NamedSourceT {
        NamedSourceT {
            name,
            inst: ctx.inst,
            insts: ctx.insts,
            registry: ctx.registry.clone(),
        }
    }
}

impl Transformation for NamedSourceT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, _v: &Value, _out: &mut dyn Collector) {
        unreachable!("source has no inputs")
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
    fn generate(&mut self, out: &mut dyn Collector) {
        let data = self
            .registry
            .get(&self.name)
            .unwrap_or_else(|| panic!("named source '{}' not registered", self.name));
        for (i, v) in data.iter().enumerate() {
            if i % self.insts == self.inst {
                out.emit(v.clone());
            }
        }
    }
}

/// `readFile`: input 0 is the (broadcast) singleton file name; each
/// instance emits its round-robin share of the lines as `Str` values.
/// The file name can change per iteration step — exactly the paper's
/// Visit Count pattern (`"pageVisitLog" + day`).
pub struct ReadFileT {
    inst: usize,
    insts: usize,
    io_dir: std::path::PathBuf,
    registry: std::sync::Arc<crate::workload::registry::Registry>,
    name: Option<String>,
}

impl ReadFileT {
    /// Create for one physical instance.
    pub fn new(ctx: &MakeCtx) -> ReadFileT {
        ReadFileT {
            inst: ctx.inst,
            insts: ctx.insts,
            io_dir: ctx.io_dir.clone(),
            registry: ctx.registry.clone(),
            name: None,
        }
    }
}

impl Transformation for ReadFileT {
    fn open_out_bag(&mut self) {
        self.name = None;
    }
    fn push_in_element(&mut self, _input: usize, v: &Value, _out: &mut dyn Collector) {
        self.name = Some(v.as_str().to_string());
    }
    fn close_in_bag(&mut self, _input: usize, out: &mut dyn Collector) {
        let name = self.name.clone().expect("readFile got no file name");
        // Names resolve against the in-memory registry first (benches use
        // this to exercise the dynamic-name path without disk noise).
        if let Some(data) = self.registry.get(&name) {
            for (i, v) in data.iter().enumerate() {
                if i % self.insts == self.inst {
                    out.emit(v.clone());
                }
            }
            return;
        }
        let path = self.io_dir.join(&name);
        let f = std::fs::File::open(&path)
            .unwrap_or_else(|e| panic!("readFile({}): {e}", path.display()));
        let reader = std::io::BufReader::new(f);
        for (i, line) in reader.lines().enumerate() {
            if i % self.insts == self.inst {
                out.emit(Value::str(line.expect("readFile line")));
            }
        }
    }
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
}

/// `writeFile`: input 0 is the (gathered) data, input 1 the singleton file
/// name. Writes one element per line at close; emits `Unit`.
pub struct WriteFileT {
    io_dir: std::path::PathBuf,
    name: Option<String>,
    data: Vec<Value>,
    data_closed: bool,
}

impl WriteFileT {
    /// Create for the single sink instance.
    pub fn new(ctx: &MakeCtx) -> WriteFileT {
        WriteFileT { io_dir: ctx.io_dir.clone(), name: None, data: Vec::new(), data_closed: false }
    }
}

impl Transformation for WriteFileT {
    fn open_out_bag(&mut self) {
        self.name = None;
        self.data.clear();
        self.data_closed = false;
    }
    fn push_in_element(&mut self, input: usize, v: &Value, _out: &mut dyn Collector) {
        if input == 0 {
            self.data.push(v.clone());
        } else {
            self.name = Some(v.as_str().to_string());
        }
    }
    fn push_in_batch(&mut self, input: usize, vs: &[Value], out: &mut dyn Collector) {
        if input == 0 {
            self.data.extend_from_slice(vs);
        } else {
            for v in vs {
                self.push_in_element(input, v, out);
            }
        }
    }
    fn close_in_bag(&mut self, input: usize, _out: &mut dyn Collector) {
        if input == 0 {
            self.data_closed = true;
        }
    }
    fn close_out_bag(&mut self, out: &mut dyn Collector) {
        let name = self.name.clone().expect("writeFile got no file name");
        let path = self.io_dir.join(&name);
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&path)
                .unwrap_or_else(|e| panic!("writeFile({}): {e}", path.display())),
        );
        for v in &self.data {
            writeln!(f, "{v}").expect("writeFile line");
        }
        f.flush().expect("writeFile flush");
        out.emit(Value::Unit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{run_once, VecCollector};

    #[test]
    fn bag_lit_partitions_round_robin() {
        let items: Vec<Value> = (0..10).map(Value::I64).collect();
        let mut total = 0;
        for inst in 0..3 {
            let ctx = MakeCtx { inst, insts: 3, ..Default::default() };
            let mut t = BagLitT::new(items.clone(), &ctx);
            let out = run_once(&mut t, &[]);
            total += out.len();
            for v in &out {
                assert_eq!(v.as_i64() as usize % 3, inst);
            }
        }
        assert_eq!(total, 10);
    }

    #[test]
    fn named_source_resolves_registry() {
        let reg = crate::workload::registry::global();
        reg.put("io_test_src", vec![Value::I64(1), Value::I64(2)]);
        let ctx = MakeCtx::default();
        let mut t = NamedSourceT::new("io_test_src".into(), &ctx);
        let out = run_once(&mut t, &[]);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("laby_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ctx = MakeCtx { io_dir: dir.clone(), ..Default::default() };

        // Write.
        let mut w = WriteFileT::new(&ctx);
        let mut out = VecCollector::default();
        w.open_out_bag();
        w.push_in_element(1, &Value::str("roundtrip.txt"), &mut out);
        w.close_in_bag(1, &mut out);
        w.push_in_element(0, &Value::I64(7), &mut out);
        w.push_in_element(0, &Value::I64(8), &mut out);
        w.close_in_bag(0, &mut out);
        w.close_out_bag(&mut out);
        assert_eq!(out.items, vec![Value::Unit]);

        // Read back.
        let mut r = ReadFileT::new(&ctx);
        let out = run_once(&mut r, &[&[Value::str("roundtrip.txt")]]);
        assert_eq!(out.len(), 2);
        assert!(out.contains(&Value::str("7")));
        assert!(out.contains(&Value::str("8")));
    }

    #[test]
    fn read_file_partitions_lines() {
        let dir = std::env::temp_dir().join("laby_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("lines.txt"), "a\nb\nc\nd\n").unwrap();
        let mut seen = Vec::new();
        for inst in 0..2 {
            let ctx = MakeCtx { inst, insts: 2, io_dir: dir.clone(), ..Default::default() };
            let mut r = ReadFileT::new(&ctx);
            let out = run_once(&mut r, &[&[Value::str("lines.txt")]]);
            seen.extend(out);
        }
        assert_eq!(seen.len(), 4);
    }
}
