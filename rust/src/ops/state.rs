//! First-class indexed solution-set state for stateful operators.
//!
//! The delta-incremental iteration engine (see `docs/incremental.md`)
//! keeps per-operator state *resident across supersteps* instead of
//! recomputing it from full bags each iteration. This module is the
//! shared vocabulary for that state, generalizing what used to be ad-hoc
//! inside individual operators (the hash-join build table in
//! `ops::join`, the reduceByKey partial map in `ops::agg`):
//!
//! * [`KeyedAcc`] — a key → accumulator map with *emit-changed*
//!   tracking (delta reduceByKey: only keys whose accumulator changed
//!   this superstep are re-circulated);
//! * [`KeyedStore`] — a key → rows solution set with per-bag *upsert*
//!   semantics (the delta-Φ store for re-aggregation loops: a changed
//!   key's arriving rows replace that key's previous rows);
//! * [`FrontierStore`] — a monotone element set (the delta-Φ store for
//!   semi-naive loops: arriving elements are the frontier, the store is
//!   the union of every frontier seen);
//! * [`SetStore`] — a plain membership set (delta distinct: the
//!   seen-set persists across supersteps so only globally-new elements
//!   pass);
//! * [`MultiMap`] — a key → rows multimap (the hash-join build table,
//!   now expressed in the shared vocabulary);
//! * [`StateSnapshot`] — the serializable form all of the above reduce
//!   to, carried by `exec::recovery` checkpoints so recovery replays a
//!   delta loop to an identical solution set.

use crate::value::Value;
use rustc_hash::{FxHashMap, FxHashSet};

/// Serializable snapshot of one operator's cross-superstep state.
///
/// Entries are canonically sorted so snapshots of equal logical state
/// compare equal byte-for-byte regardless of hash-map iteration order —
/// the chaos suites rely on this to assert recovery restored solution
/// sets exactly.
#[derive(Clone, Debug, PartialEq)]
pub enum StateSnapshot {
    /// [`KeyedAcc`]: sorted `(key, accumulator)` pairs.
    Keyed(Vec<(Value, Value)>),
    /// [`KeyedStore`]: sorted `(key, rows)` entries plus the
    /// first-bag flag (whether the Φ has merged its init bag yet).
    KeyedMulti {
        /// Sorted `(key, rows)` entries.
        entries: Vec<(Value, Vec<Value>)>,
        /// True until the first bag of the current loop entry is merged.
        first: bool,
    },
    /// [`FrontierStore`]: sorted elements plus flags.
    Frontier {
        /// Stored elements, sorted (duplicates possible while `raw`).
        items: Vec<Value>,
        /// True until the first bag of the current loop entry is merged.
        first: bool,
        /// True while the store still holds the raw (possibly
        /// duplicate-bearing) init bag, before the first delta merge
        /// canonicalizes it into a set.
        raw: bool,
    },
    /// [`SetStore`]: sorted members.
    Set(Vec<Value>),
}

impl StateSnapshot {
    /// Number of stored rows (solution-set size) in the snapshot.
    pub fn rows(&self) -> u64 {
        match self {
            StateSnapshot::Keyed(kv) => kv.len() as u64,
            StateSnapshot::KeyedMulti { entries, .. } => {
                entries.iter().map(|(_, rows)| rows.len() as u64).sum()
            }
            StateSnapshot::Frontier { items, .. } => items.len() as u64,
            StateSnapshot::Set(items) => items.len() as u64,
        }
    }
}

/// Key → accumulator map with emit-changed tracking (delta reduceByKey).
///
/// In full-recompute mode the caller clears it per bag and drains all
/// pairs at close; in delta mode the map persists across supersteps and
/// only the keys touched *with a different resulting accumulator* are
/// emitted — the changed set is the delta the loop circulates.
#[derive(Default)]
pub struct KeyedAcc {
    map: FxHashMap<Value, Value>,
    changed: FxHashSet<Value>,
}

impl KeyedAcc {
    /// Empty accumulator.
    pub fn new() -> KeyedAcc {
        KeyedAcc::default()
    }

    /// Drop all state (full-recompute open, or loop re-entry reset).
    pub fn clear(&mut self) {
        self.map.clear();
        self.changed.clear();
    }

    /// Number of keys held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if no keys are held.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fold `v` into the accumulator for `k` (no change tracking — the
    /// full-recompute path, where every key is emitted anyway).
    pub fn merge(&mut self, k: Value, v: Value, f: impl FnOnce(&Value, &Value) -> Value) {
        match self.map.get_mut(&k) {
            Some(a) => *a = f(a, &v),
            None => {
                self.map.insert(k, v);
            }
        }
    }

    /// Fold `v` into the accumulator for `k`, recording `k` as changed
    /// when the resulting accumulator differs from the previous one (or
    /// the key is new).
    pub fn merge_tracked(
        &mut self,
        k: Value,
        v: Value,
        f: impl FnOnce(&Value, &Value) -> Value,
    ) {
        match self.map.get_mut(&k) {
            Some(a) => {
                let nv = f(a, &v);
                if *a != nv {
                    *a = nv;
                    self.changed.insert(k);
                }
            }
            None => {
                self.changed.insert(k.clone());
                self.map.insert(k, v);
            }
        }
    }

    /// Emit every `(key, acc)` pair and drop them (full-recompute close).
    pub fn drain_all(&mut self, out: &mut Vec<Value>) {
        for (k, a) in self.map.drain() {
            out.push(Value::pair(k, a));
        }
        self.changed.clear();
    }

    /// Emit the `(key, acc)` pairs whose accumulator changed since the
    /// last call, keeping the map intact (delta close).
    pub fn take_changed(&mut self, out: &mut Vec<Value>) {
        for k in self.changed.drain() {
            if let Some(a) = self.map.get(&k) {
                out.push(Value::pair(k, a.clone()));
            }
        }
    }

    /// Canonical snapshot of the retained map. The per-bag changed set
    /// is always empty at a quiescent checkpoint cut and is not carried.
    pub fn snapshot(&self) -> StateSnapshot {
        let mut kv: Vec<(Value, Value)> =
            self.map.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        kv.sort();
        StateSnapshot::Keyed(kv)
    }

    /// Restore from a snapshot produced by [`KeyedAcc::snapshot`].
    pub fn restore(&mut self, snap: &StateSnapshot) {
        if let StateSnapshot::Keyed(kv) = snap {
            self.map = kv.iter().cloned().collect();
            self.changed.clear();
        }
    }
}

/// Key → rows solution set with per-bag upsert semantics (the delta-Φ
/// store for re-aggregation loops).
///
/// Within one bag, the *first* arrival of a key replaces that key's
/// previous rows and later arrivals of the same key append — so a bag
/// carrying duplicate keys (e.g. a raw init bag) is stored with its
/// multiplicities, while a changed-key delta from a later superstep
/// cleanly supersedes the stale rows.
#[derive(Default)]
pub struct KeyedStore {
    map: FxHashMap<Value, Vec<Value>>,
    touched: FxHashSet<Value>,
    first: bool,
}

impl KeyedStore {
    /// Empty store, positioned before its first bag.
    pub fn new() -> KeyedStore {
        KeyedStore { map: FxHashMap::default(), touched: FxHashSet::default(), first: true }
    }

    /// Start a new arriving bag: resets per-bag touch tracking. Returns
    /// true iff this is the first bag since construction or
    /// [`KeyedStore::reset`] — the Φ re-emits arriving items downstream
    /// only on that first (init) bag, when the loop's retained
    /// accumulators are still empty.
    pub fn begin_bag(&mut self) -> bool {
        self.touched.clear();
        std::mem::take(&mut self.first)
    }

    /// Upsert one arriving row (keyed by `v.key()`).
    pub fn upsert(&mut self, v: &Value) {
        let k = v.key().clone();
        if self.touched.insert(k.clone()) {
            self.map.insert(k, vec![v.clone()]);
        } else if let Some(rows) = self.map.get_mut(&k) {
            rows.push(v.clone());
        }
    }

    /// Total stored rows (with multiplicity).
    pub fn rows(&self) -> u64 {
        self.map.values().map(|r| r.len() as u64).sum()
    }

    /// Append the full solution set to `out` (exit-edge materialization).
    pub fn materialize(&self, out: &mut Vec<Value>) {
        for rows in self.map.values() {
            out.extend(rows.iter().cloned());
        }
    }

    /// Drop all state and rearm the first-bag flag (loop re-entry).
    pub fn reset(&mut self) {
        self.map.clear();
        self.touched.clear();
        self.first = true;
    }

    /// Canonical snapshot.
    pub fn snapshot(&self) -> StateSnapshot {
        let mut entries: Vec<(Value, Vec<Value>)> =
            self.map.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        entries.sort();
        StateSnapshot::KeyedMulti { entries, first: self.first }
    }

    /// Restore from a snapshot produced by [`KeyedStore::snapshot`].
    pub fn restore(&mut self, snap: &StateSnapshot) {
        if let StateSnapshot::KeyedMulti { entries, first } = snap {
            self.map = entries.iter().cloned().collect();
            self.touched.clear();
            self.first = *first;
        }
    }
}

/// Monotone element set for semi-naive loops (the delta-Φ store for
/// frontier iteration).
///
/// The first bag (the init bag) is stored *raw*, duplicates and all, so
/// a zero-trip loop materializes exactly the init multiset. The first
/// delta merge canonicalizes the store into a set — matching the full
/// recompute, where one pass through `distinct` collapses duplicates.
#[derive(Default)]
pub struct FrontierStore {
    items: Vec<Value>,
    seen: FxHashSet<Value>,
    first: bool,
    raw: bool,
}

impl FrontierStore {
    /// Empty store, positioned before its first bag.
    pub fn new() -> FrontierStore {
        FrontierStore {
            items: Vec::new(),
            seen: FxHashSet::default(),
            first: true,
            raw: true,
        }
    }

    /// Start a new arriving bag. Returns true iff this is the init bag.
    /// On the first non-init bag, collapses raw init duplicates.
    pub fn begin_bag(&mut self) -> bool {
        if self.first {
            self.first = false;
            return true;
        }
        if self.raw {
            let mut seen = FxHashSet::default();
            self.items.retain(|v| seen.insert(v.clone()));
            self.raw = false;
        }
        false
    }

    /// Store one element of the raw init bag (keeps duplicates).
    pub fn push_raw(&mut self, v: &Value) {
        self.seen.insert(v.clone());
        self.items.push(v.clone());
    }

    /// Insert one frontier element; no-op if already present.
    pub fn insert(&mut self, v: &Value) {
        if self.seen.insert(v.clone()) {
            self.items.push(v.clone());
        }
    }

    /// Total stored rows (with init multiplicity while raw).
    pub fn rows(&self) -> u64 {
        self.items.len() as u64
    }

    /// Append the full solution set to `out` (exit-edge materialization).
    pub fn materialize(&self, out: &mut Vec<Value>) {
        out.extend(self.items.iter().cloned());
    }

    /// Drop all state and rearm the first-bag flag (loop re-entry).
    pub fn reset(&mut self) {
        self.items.clear();
        self.seen.clear();
        self.first = true;
        self.raw = true;
    }

    /// Canonical snapshot (items sorted; multiset order is irrelevant).
    pub fn snapshot(&self) -> StateSnapshot {
        let mut items = self.items.clone();
        items.sort();
        StateSnapshot::Frontier { items, first: self.first, raw: self.raw }
    }

    /// Restore from a snapshot produced by [`FrontierStore::snapshot`].
    pub fn restore(&mut self, snap: &StateSnapshot) {
        if let StateSnapshot::Frontier { items, first, raw } = snap {
            self.items = items.clone();
            self.seen = items.iter().cloned().collect();
            self.first = *first;
            self.raw = *raw;
        }
    }
}

/// Plain membership set (the distinct seen-set, persisted across
/// supersteps in delta mode).
#[derive(Default)]
pub struct SetStore {
    seen: FxHashSet<Value>,
}

impl SetStore {
    /// Empty set.
    pub fn new() -> SetStore {
        SetStore::default()
    }

    /// Insert; true iff the element was new.
    pub fn insert(&mut self, v: &Value) -> bool {
        self.seen.insert(v.clone())
    }

    /// Drop all members.
    pub fn clear(&mut self) {
        self.seen.clear();
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.seen.len()
    }

    /// True if no members are held.
    pub fn is_empty(&self) -> bool {
        self.seen.is_empty()
    }

    /// Canonical snapshot.
    pub fn snapshot(&self) -> StateSnapshot {
        let mut items: Vec<Value> = self.seen.iter().cloned().collect();
        items.sort();
        StateSnapshot::Set(items)
    }

    /// Restore from a snapshot produced by [`SetStore::snapshot`].
    pub fn restore(&mut self, snap: &StateSnapshot) {
        if let StateSnapshot::Set(items) = snap {
            self.seen = items.iter().cloned().collect();
        }
    }
}

/// Key → rows multimap — the hash-join build table, shared vocabulary
/// form. (The build side is rebuilt from retained input buffers on
/// recovery, so it does not flow through [`StateSnapshot`]; it lives
/// here so *all* cross-bag operator state speaks one interface.)
#[derive(Default)]
pub struct MultiMap {
    map: FxHashMap<Value, Vec<Value>>,
}

impl MultiMap {
    /// Empty multimap.
    pub fn new() -> MultiMap {
        MultiMap::default()
    }

    /// Append one row under `k`.
    pub fn push(&mut self, k: Value, v: Value) {
        self.map.entry(k).or_default().push(v);
    }

    /// Rows stored under `k`, if any.
    pub fn get(&self, k: &Value) -> Option<&[Value]> {
        self.map.get(k).map(|v| v.as_slice())
    }

    /// Drop all rows.
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Total stored rows (with multiplicity).
    pub fn rows(&self) -> u64 {
        self.map.values().map(|r| r.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(k: i64, v: i64) -> Value {
        Value::pair(Value::I64(k), Value::I64(v))
    }

    #[test]
    fn keyed_acc_tracks_changed_keys_only() {
        let mut acc = KeyedAcc::new();
        let sum = |a: &Value, b: &Value| Value::I64(a.as_i64() + b.as_i64());
        acc.merge_tracked(Value::I64(1), Value::I64(5), sum);
        acc.merge_tracked(Value::I64(2), Value::I64(7), sum);
        let mut out = Vec::new();
        acc.take_changed(&mut out);
        out.sort();
        assert_eq!(out, vec![kv(1, 5), kv(2, 7)]);
        // Second step: only key 1 changes; adding zero to key 2 is not a change.
        acc.merge_tracked(Value::I64(1), Value::I64(3), sum);
        acc.merge_tracked(Value::I64(2), Value::I64(0), sum);
        let mut out2 = Vec::new();
        acc.take_changed(&mut out2);
        assert_eq!(out2, vec![kv(1, 8)]);
        assert_eq!(acc.len(), 2);
    }

    #[test]
    fn keyed_acc_snapshot_roundtrip_is_canonical() {
        let mut acc = KeyedAcc::new();
        let sum = |a: &Value, b: &Value| Value::I64(a.as_i64() + b.as_i64());
        for i in 0..10 {
            acc.merge_tracked(Value::I64(i % 3), Value::I64(i), sum);
        }
        let snap = acc.snapshot();
        let mut acc2 = KeyedAcc::new();
        acc2.restore(&snap);
        assert_eq!(snap, acc2.snapshot());
        assert_eq!(snap.rows(), 3);
    }

    #[test]
    fn keyed_store_upsert_replaces_then_appends_within_bag() {
        let mut s = KeyedStore::new();
        assert!(s.begin_bag(), "first bag");
        // Init bag with a duplicate key: both rows kept.
        s.upsert(&kv(1, 10));
        s.upsert(&kv(1, 20));
        assert_eq!(s.rows(), 2);
        // Next bag: first arrival of key 1 replaces both rows.
        assert!(!s.begin_bag());
        s.upsert(&kv(1, 30));
        assert_eq!(s.rows(), 1);
        let mut out = Vec::new();
        s.materialize(&mut out);
        assert_eq!(out, vec![kv(1, 30)]);
    }

    #[test]
    fn keyed_store_reset_rearms_first() {
        let mut s = KeyedStore::new();
        s.begin_bag();
        s.upsert(&kv(1, 1));
        s.reset();
        assert_eq!(s.rows(), 0);
        assert!(s.begin_bag());
    }

    #[test]
    fn frontier_store_keeps_raw_init_until_first_merge() {
        let mut f = FrontierStore::new();
        assert!(f.begin_bag());
        f.push_raw(&Value::I64(1));
        f.push_raw(&Value::I64(1)); // zero-trip exit must keep the duplicate
        assert_eq!(f.rows(), 2);
        // First merge collapses the raw duplicates, then dedups inserts.
        assert!(!f.begin_bag());
        f.insert(&Value::I64(1));
        f.insert(&Value::I64(2));
        assert_eq!(f.rows(), 2);
        let mut out = Vec::new();
        f.materialize(&mut out);
        out.sort();
        assert_eq!(out, vec![Value::I64(1), Value::I64(2)]);
    }

    #[test]
    fn frontier_snapshot_roundtrip() {
        let mut f = FrontierStore::new();
        f.begin_bag();
        f.push_raw(&Value::I64(3));
        f.push_raw(&Value::I64(3));
        let snap = f.snapshot();
        let mut f2 = FrontierStore::new();
        f2.restore(&snap);
        assert_eq!(f2.snapshot(), snap);
        // Restored raw store still canonicalizes on first merge.
        assert!(!f2.begin_bag());
        assert_eq!(f2.rows(), 1);
    }

    #[test]
    fn set_store_roundtrip() {
        let mut s = SetStore::new();
        assert!(s.insert(&Value::I64(1)));
        assert!(!s.insert(&Value::I64(1)));
        let snap = s.snapshot();
        let mut s2 = SetStore::new();
        s2.restore(&snap);
        assert!(!s2.insert(&Value::I64(1)));
        assert!(s2.insert(&Value::I64(2)));
    }

    #[test]
    fn multimap_appends_per_key() {
        let mut m = MultiMap::new();
        m.push(Value::I64(1), Value::str("a"));
        m.push(Value::I64(1), Value::str("b"));
        assert_eq!(m.get(&Value::I64(1)).unwrap().len(), 2);
        assert!(m.get(&Value::I64(2)).is_none());
        assert_eq!(m.rows(), 2);
    }
}
