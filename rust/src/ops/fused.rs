//! The fused element-wise operator produced by `opt::fuse`: a maximal
//! linear chain of map/filter/flatMap stages executed inside one physical
//! operator instance. Fusing a k-stage chain removes k-1 per-element
//! dispatches, k-1 intermediate bags per step, and all the coordination
//! messages (closes, conditional-output watchers) the intermediate nodes
//! would have cost.
//!
//! The batch kernel (`push_in_batch`) runs the pipeline **stage-at-a-time
//! over the whole batch** through two ping-pong buffers — an iterative,
//! non-recursive loop with no per-element virtual dispatch. Because every
//! stage preserves the per-element order of its input (flatMap expansions
//! stay contiguous), the breadth-first batch order is identical to the
//! depth-first element order of `apply_stages`.
//!
//! The kernel also counts each stage's output rows (`stage_rows`),
//! which the engine folds into per-node metrics: interior filter/flatMap
//! cardinalities — invisible from the fused tail's output count — become
//! observable to adaptive re-optimization via the stage-parallel lineage
//! recorded by `opt::fuse`.

use super::{Collector, Transformation};
use crate::bag::ColumnBatch;
use crate::frontend::FusedStage;
use crate::opt::types::TypedStage;
use crate::value::{ElemType, Value};

/// Run `v` through `stages[idx..]`, handing survivors to `emit`.
fn run_stages(stages: &[FusedStage], idx: usize, v: &Value, emit: &mut dyn FnMut(Value)) {
    let Some(stage) = stages.get(idx) else {
        emit(v.clone());
        return;
    };
    match stage {
        FusedStage::Map(udf) => run_stages(stages, idx + 1, &udf.call(v), emit),
        FusedStage::Filter(udf) => {
            if udf.call(v).as_bool() {
                run_stages(stages, idx + 1, v, emit);
            }
        }
        FusedStage::FlatMap(udf) => {
            for x in udf.call(v) {
                run_stages(stages, idx + 1, &x, emit);
            }
        }
    }
}

/// Apply a full stage pipeline to one element (shared with the baseline
/// interpreters so every executor agrees on fused semantics).
pub fn apply_stages(stages: &[FusedStage], v: &Value, emit: &mut dyn FnMut(Value)) {
    run_stages(stages, 0, v, emit);
}

/// A fully compiled columnar pipeline for the chain: the input layout to
/// decode plus one monomorphic kernel per stage (all-or-nothing, see
/// [`crate::opt::types::compile_chain`]).
pub struct TypedChain {
    /// Element type of the chain's input edge (decode layout).
    pub in_ty: ElemType,
    /// Compiled stages, parallel to the dynamic stage list.
    pub stages: Vec<TypedStage>,
}

/// Fused chain transformation (fully pipelined; the only state is the
/// reusable batch buffers and the per-stage row counters).
pub struct FusedT {
    stages: Vec<FusedStage>,
    /// Columnar pipeline compiled from the stages when every lambda and
    /// the inferred input type allow; advisory — each batch re-verifies
    /// its layout during decode and falls back to the dynamic loop.
    typed: Option<TypedChain>,
    /// Ping-pong buffers for the stage-at-a-time batch loop.
    cur: Vec<Value>,
    next: Vec<Value>,
    /// Output rows per stage since the last [`Transformation::take_stage_rows`]
    /// (stage-parallel with `stages`).
    stage_rows: Vec<u64>,
    /// Scratch for the typed pipeline's per-stage counts — committed into
    /// `stage_rows` only when the whole chain succeeds, so a fallback
    /// never double-counts.
    typed_rows: Vec<u64>,
    /// Rows consumed directly from the borrowed input batch — no upfront
    /// clone of the batch (stage-0 borrow or columnar decode). Drained by
    /// the engine into `exec.fused_borrowed_rows`.
    borrowed_rows: u64,
}

impl FusedT {
    /// Create from the chain's stages, in application order.
    pub fn new(stages: Vec<FusedStage>) -> FusedT {
        FusedT::with_typed(stages, None)
    }

    /// Create with an optional compiled columnar pipeline (engine path,
    /// gated by `opt.columnar`).
    pub fn with_typed(stages: Vec<FusedStage>, typed: Option<TypedChain>) -> FusedT {
        let n = stages.len();
        FusedT {
            stages,
            typed,
            cur: Vec::new(),
            next: Vec::new(),
            stage_rows: vec![0; n],
            typed_rows: Vec::new(),
            borrowed_rows: 0,
        }
    }

    /// Per-stage output rows accumulated so far (tests).
    pub fn stage_rows(&self) -> &[u64] {
        &self.stage_rows
    }

    /// Rows consumed without the upfront batch clone so far (tests; the
    /// engine drains via [`Transformation::take_borrowed_rows`]).
    pub fn borrowed_rows(&self) -> u64 {
        self.borrowed_rows
    }

    /// Run the compiled columnar pipeline over one batch. Returns `false`
    /// (with no counters touched) when the batch layout defeats the
    /// compiled kernels — the caller then runs the dynamic loop.
    fn push_typed(&mut self, vs: &[Value], out: &mut dyn Collector) -> bool {
        // Destructure for disjoint borrows: the compiled chain is read
        // while the counters are written.
        let Self { typed, typed_rows, stage_rows, borrowed_rows, .. } = self;
        let Some(tc) = typed else { return false };
        let Some(mut cols) = ColumnBatch::from_values(vs, &tc.in_ty) else {
            return false;
        };
        typed_rows.clear();
        // Selection bitmap: the first filter stage allocates a
        // row-parallel mask and from then on filters only CLEAR bits
        // (`filter_mask`) and maps skip dead lanes (`map_batch_masked`)
        // — zero data movement inside the chain. Survivors are compacted
        // exactly once, at emission. `selected` tracks the live-row count
        // (the logical cardinality every stage's row counter reports).
        let mut mask: Option<Vec<bool>> = None;
        let mut selected = cols.len();
        for st in &tc.stages {
            match st {
                TypedStage::Map(u) => {
                    let next = match &mask {
                        Some(m) => u.map_batch_masked(&cols, m),
                        None => u.map_batch(&cols),
                    };
                    match next {
                        Some(next) => cols = next,
                        None => return false,
                    }
                }
                TypedStage::Filter(u) => {
                    let m = mask.get_or_insert_with(|| vec![true; cols.len()]);
                    match u.filter_mask(&cols, m) {
                        Some(kept) => selected = kept,
                        None => return false,
                    }
                }
            }
            typed_rows.push(selected as u64);
        }
        if let Some(m) = &mask {
            cols.compact(m);
        }
        debug_assert_eq!(cols.len(), selected, "mask compaction matches live count");
        for (i, r) in typed_rows.iter().enumerate() {
            stage_rows[i] += r;
        }
        *borrowed_rows += vs.len() as u64;
        out.emit_columns(cols);
        true
    }
}

impl Transformation for FusedT {
    fn open_out_bag(&mut self) {}

    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        // The pre-batching execution, kept verbatim as the element-path
        // reference: depth-first recursion, direct emits, NO per-stage
        // counting (the batch kernel is the counting path —
        // `record_observed` detects incomplete stage counts and falls
        // back to the lineage walk).
        run_stages(&self.stages, 0, v, &mut |x| out.emit(x));
    }

    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        if self.stages.is_empty() {
            let mut buf = vs.to_vec();
            out.emit_batch(&mut buf);
            return;
        }
        if self.push_typed(vs, out) {
            return;
        }
        // Stage 0 runs over the BORROWED input — no upfront clone of the
        // whole batch. Only filter survivors are cloned (everything a map
        // or flatMap produces is freshly owned already), and from stage 1
        // on the ping-pong loop moves owned values.
        self.cur.clear();
        match &self.stages[0] {
            FusedStage::Map(udf) => {
                self.cur.reserve(vs.len());
                for v in vs {
                    self.cur.push(udf.call(v));
                }
            }
            FusedStage::Filter(udf) => {
                for v in vs {
                    if udf.call(v).as_bool() {
                        self.cur.push(v.clone());
                    }
                }
            }
            FusedStage::FlatMap(udf) => {
                for v in vs {
                    self.cur.extend(udf.call(v));
                }
            }
        }
        self.stage_rows[0] += self.cur.len() as u64;
        self.borrowed_rows += vs.len() as u64;
        for (i, stage) in self.stages.iter().enumerate().skip(1) {
            self.next.clear();
            match stage {
                FusedStage::Map(udf) => {
                    self.next.reserve(self.cur.len());
                    for v in &self.cur {
                        self.next.push(udf.call(v));
                    }
                }
                FusedStage::Filter(udf) => {
                    // Survivors are MOVED, not cloned (the element path
                    // clones every survivor out of the borrowed input).
                    for v in self.cur.drain(..) {
                        if udf.call(&v).as_bool() {
                            self.next.push(v);
                        }
                    }
                }
                FusedStage::FlatMap(udf) => {
                    for v in &self.cur {
                        self.next.extend(udf.call(v));
                    }
                }
            }
            self.stage_rows[i] += self.next.len() as u64;
            std::mem::swap(&mut self.cur, &mut self.next);
        }
        out.emit_batch(&mut self.cur);
    }

    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}

    fn take_stage_rows(&mut self) -> Option<Vec<u64>> {
        if self.stages.is_empty() {
            return None;
        }
        Some(std::mem::replace(&mut self.stage_rows, vec![0; self.stages.len()]))
    }

    fn take_borrowed_rows(&mut self) -> u64 {
        std::mem::take(&mut self.borrowed_rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{Udf1, UdfN};
    use crate::ops::{run_once, run_once_chunked};

    fn i(v: i64) -> Value {
        Value::I64(v)
    }

    fn chain() -> Vec<FusedStage> {
        vec![
            FusedStage::Map(Udf1::new("x+1", |v: &Value| i(v.as_i64() + 1))),
            FusedStage::Filter(Udf1::new("even", |v: &Value| Value::Bool(v.as_i64() % 2 == 0))),
            FusedStage::Map(Udf1::new("x*10", |v: &Value| i(v.as_i64() * 10))),
        ]
    }

    #[test]
    fn fused_chain_matches_sequential_application() {
        let mut t = FusedT::new(chain());
        let out = run_once(&mut t, &[&[i(1), i(2), i(3), i(4)]]);
        // +1 -> [2,3,4,5]; keep even -> [2,4]; *10 -> [20,40].
        assert_eq!(out, vec![i(20), i(40)]);
    }

    #[test]
    fn flat_map_stage_expands_through_later_stages() {
        let stages = vec![
            FusedStage::FlatMap(UdfN::new("dup", |v: &Value| vec![v.clone(), v.clone()])),
            FusedStage::Map(Udf1::new("x+1", |v: &Value| i(v.as_i64() + 1))),
        ];
        let mut t = FusedT::new(stages);
        let out = run_once(&mut t, &[&[i(7)]]);
        assert_eq!(out, vec![i(8), i(8)]);
    }

    #[test]
    fn empty_stage_list_is_identity() {
        let mut t = FusedT::new(Vec::new());
        let out = run_once(&mut t, &[&[i(5)]]);
        assert_eq!(out, vec![i(5)]);
    }

    #[test]
    fn apply_stages_helper_agrees_with_operator() {
        let mut got = Vec::new();
        apply_stages(&chain(), &i(3), &mut |x| got.push(x));
        assert_eq!(got, vec![i(40)]);
    }

    #[test]
    fn batch_order_matches_depth_first_element_order() {
        // flatMap mid-chain: the batch loop runs breadth-first, the
        // apply_stages helper depth-first — outputs must align exactly.
        let stages = vec![
            FusedStage::Map(Udf1::new("x*2", |v: &Value| i(v.as_i64() * 2))),
            FusedStage::FlatMap(UdfN::new("span", |v: &Value| {
                vec![v.clone(), i(v.as_i64() + 1)]
            })),
            FusedStage::Filter(Udf1::new("not3", |v: &Value| Value::Bool(v.as_i64() % 3 != 0))),
        ];
        let input: Vec<Value> = (0..9).map(i).collect();
        let mut want = Vec::new();
        for v in &input {
            apply_stages(&stages, v, &mut |x| want.push(x));
        }
        let whole = run_once(&mut FusedT::new(stages.clone()), &[&input]);
        assert_eq!(whole, want);
        for chunk in [1usize, 2, 7] {
            let got = run_once_chunked(&mut FusedT::new(stages.clone()), &[&input], chunk);
            assert_eq!(got, want, "chunk={chunk}");
        }
    }

    #[test]
    fn stage_rows_count_interior_cardinalities() {
        let input = [i(1), i(2), i(3), i(4)];
        // Batch delivery: +1 -> 4 rows; keep even -> 2 rows; *10 -> 2.
        let mut t = FusedT::new(chain());
        let batched = run_once_chunked(&mut t, &[&input], 256);
        assert_eq!(t.stage_rows(), &[4, 2, 2]);
        // take_stage_rows drains the counters.
        assert_eq!(t.take_stage_rows(), Some(vec![4, 2, 2]));
        assert_eq!(t.stage_rows(), &[0, 0, 0]);
        // Element-at-a-time delivery is the UNCOUNTED legacy reference:
        // identical output, zero stage counts (`record_observed` detects
        // the incomplete counts and uses the lineage-walk fallback).
        let mut e = FusedT::new(chain());
        let element = run_once(&mut e, &[&input]);
        assert_eq!(element, batched);
        assert_eq!(e.stage_rows(), &[0, 0, 0]);
        // An empty chain has nothing to report.
        assert_eq!(FusedT::new(Vec::new()).take_stage_rows(), None);
    }

    #[test]
    fn batch_path_borrows_input_instead_of_cloning() {
        use crate::ops::Transformation;
        let input = [i(1), i(2), i(3), i(4)];
        // Batch delivery consumes the borrowed input directly: every row
        // counts toward the borrowed counter, whatever the first stage is.
        let mut t = FusedT::new(chain());
        run_once_chunked(&mut t, &[&input], 256);
        assert_eq!(t.borrowed_rows(), 4);
        assert_eq!(t.take_borrowed_rows(), 4, "drains");
        assert_eq!(t.borrowed_rows(), 0);
        // A filter-first chain clones only survivors — still borrowed.
        let stages = vec![
            FusedStage::Filter(Udf1::new("odd", |v: &Value| Value::Bool(v.as_i64() % 2 == 1))),
            FusedStage::Map(Udf1::new("x+1", |v: &Value| i(v.as_i64() + 1))),
        ];
        let mut f = FusedT::new(stages);
        let out = run_once_chunked(&mut f, &[&input], 256);
        assert_eq!(out, vec![i(2), i(4)]);
        assert_eq!(f.take_borrowed_rows(), 4);
        // The element path never engages the batch kernel.
        let mut e = FusedT::new(chain());
        run_once(&mut e, &[&input]);
        assert_eq!(e.take_borrowed_rows(), 0);
    }

    fn parsed_udf1(src: &str) -> Udf1 {
        use crate::frontend::{ast, interp_expr, lexer::lex, parser};
        let ast = parser::parse(&lex(&format!("x = {src};")).unwrap()).unwrap();
        match &ast.stmts[0] {
            ast::Stmt::Assign(_, ast::Expr::Lambda(ps, body)) => {
                interp_expr::compile_udf1(ps.clone(), (**body).clone(), "t".into()).unwrap()
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn typed_pipeline_matches_dynamic_chain() {
        use crate::opt::types::compile_chain;
        use crate::value::ElemType;
        let stages = vec![
            FusedStage::Map(parsed_udf1("|x| x + 1")),
            FusedStage::Filter(parsed_udf1("|x| x % 2 == 0")),
            FusedStage::Map(parsed_udf1("|x| x * 10")),
        ];
        let (tstages, out_ty) = compile_chain(&stages, &ElemType::I64).expect("chain compiles");
        assert_eq!(out_ty, ElemType::I64);
        let input: Vec<Value> = (0..23).map(i).collect();
        let dynamic = run_once(&mut FusedT::new(stages.clone()), &[&input]);
        for chunk in [1usize, 2, 7, 256] {
            let mut t = FusedT::with_typed(
                stages.clone(),
                Some(TypedChain { in_ty: ElemType::I64, stages: tstages.clone() }),
            );
            let got = run_once_chunked(&mut t, &[&input], chunk);
            assert_eq!(got, dynamic, "chunk={chunk}");
            assert_eq!(t.stage_rows().len(), 3);
            assert_eq!(t.take_borrowed_rows(), input.len() as u64);
        }
        // A layout-defeating batch (strings on an i64-compiled chain)
        // must fall back to the dynamic loop and stay correct.
        let mut t = FusedT::with_typed(
            vec![FusedStage::Map(parsed_udf1("|x| x"))],
            Some(TypedChain {
                in_ty: ElemType::I64,
                stages: compile_chain(&[FusedStage::Map(parsed_udf1("|x| x"))], &ElemType::I64)
                    .unwrap()
                    .0,
            }),
        );
        let strs = [Value::str("a"), Value::str("b")];
        let got = run_once_chunked(&mut t, &[&strs], 256);
        assert_eq!(got, strs.to_vec(), "mismatched layout falls back, stays correct");
    }

    #[test]
    fn masked_multi_filter_chain_compacts_once_and_matches_dynamic() {
        use crate::opt::types::compile_chain;
        use crate::value::ElemType;
        // filter → map → filter → map: the first filter allocates the
        // selection mask, the interior map runs masked (dead lanes
        // skipped), the second filter clears more bits, and survivors
        // are compacted exactly once at emission.
        let stages = vec![
            FusedStage::Filter(parsed_udf1("|x| x % 2 == 0")),
            FusedStage::Map(parsed_udf1("|x| x + 100")),
            FusedStage::Filter(parsed_udf1("|x| x % 3 == 0")),
            FusedStage::Map(parsed_udf1("|x| x * 2")),
        ];
        let (tstages, _) = compile_chain(&stages, &ElemType::I64).unwrap();
        let input: Vec<Value> = (0..30).map(i).collect();
        let dynamic = run_once(&mut FusedT::new(stages.clone()), &[&input]);
        for chunk in [1usize, 7, 256] {
            let mut t = FusedT::with_typed(
                stages.clone(),
                Some(TypedChain { in_ty: ElemType::I64, stages: tstages.clone() }),
            );
            let got = run_once_chunked(&mut t, &[&input], chunk);
            assert_eq!(got, dynamic, "chunk={chunk}");
        }
        // Whole-batch delivery: 30 → 15 even → 15 mapped → 5 divisible
        // by 3 (even x with x+100 ≡ 0 mod 3) → 5 doubled. Interior
        // counters see the LIVE row counts, not the padded lane count.
        let mut t = FusedT::with_typed(
            stages,
            Some(TypedChain { in_ty: ElemType::I64, stages: tstages }),
        );
        let got = run_once_chunked(&mut t, &[&input], 256);
        assert_eq!(got.len(), 5);
        assert_eq!(t.stage_rows(), &[15, 15, 5, 5]);
    }

    #[test]
    fn typed_pipeline_counts_interior_stage_rows() {
        use crate::opt::types::compile_chain;
        use crate::value::ElemType;
        let stages = vec![
            FusedStage::Map(parsed_udf1("|x| x + 1")),
            FusedStage::Filter(parsed_udf1("|x| x % 2 == 0")),
            FusedStage::Map(parsed_udf1("|x| x * 10")),
        ];
        let (tstages, _) = compile_chain(&stages, &ElemType::I64).unwrap();
        let mut t = FusedT::with_typed(
            stages,
            Some(TypedChain { in_ty: ElemType::I64, stages: tstages }),
        );
        let input = [i(1), i(2), i(3), i(4)];
        let out = run_once_chunked(&mut t, &[&input], 256);
        assert_eq!(out, vec![i(20), i(40)]);
        assert_eq!(t.stage_rows(), &[4, 2, 2], "typed path feeds the same counters");
    }
}
