//! The fused element-wise operator produced by `opt::fuse`: a maximal
//! linear chain of map/filter/flatMap stages executed inside one physical
//! operator instance. Fusing a k-stage chain removes k-1 per-element
//! dispatches, k-1 intermediate bags per step, and all the coordination
//! messages (closes, conditional-output watchers) the intermediate nodes
//! would have cost.
//!
//! The batch kernel (`push_in_batch`) runs the pipeline **stage-at-a-time
//! over the whole batch** through two ping-pong buffers — an iterative,
//! non-recursive loop with no per-element virtual dispatch. Because every
//! stage preserves the per-element order of its input (flatMap expansions
//! stay contiguous), the breadth-first batch order is identical to the
//! depth-first element order of `apply_stages`.
//!
//! The kernel also counts each stage's output rows (`stage_rows`),
//! which the engine folds into per-node metrics: interior filter/flatMap
//! cardinalities — invisible from the fused tail's output count — become
//! observable to adaptive re-optimization via the stage-parallel lineage
//! recorded by `opt::fuse`.

use super::{Collector, Transformation};
use crate::frontend::FusedStage;
use crate::value::Value;

/// Run `v` through `stages[idx..]`, handing survivors to `emit`.
fn run_stages(stages: &[FusedStage], idx: usize, v: &Value, emit: &mut dyn FnMut(Value)) {
    let Some(stage) = stages.get(idx) else {
        emit(v.clone());
        return;
    };
    match stage {
        FusedStage::Map(udf) => run_stages(stages, idx + 1, &udf.call(v), emit),
        FusedStage::Filter(udf) => {
            if udf.call(v).as_bool() {
                run_stages(stages, idx + 1, v, emit);
            }
        }
        FusedStage::FlatMap(udf) => {
            for x in udf.call(v) {
                run_stages(stages, idx + 1, &x, emit);
            }
        }
    }
}

/// Apply a full stage pipeline to one element (shared with the baseline
/// interpreters so every executor agrees on fused semantics).
pub fn apply_stages(stages: &[FusedStage], v: &Value, emit: &mut dyn FnMut(Value)) {
    run_stages(stages, 0, v, emit);
}

/// Fused chain transformation (fully pipelined; the only state is the
/// reusable batch buffers and the per-stage row counters).
pub struct FusedT {
    stages: Vec<FusedStage>,
    /// Ping-pong buffers for the stage-at-a-time batch loop.
    cur: Vec<Value>,
    next: Vec<Value>,
    /// Output rows per stage since the last [`Transformation::take_stage_rows`]
    /// (stage-parallel with `stages`).
    stage_rows: Vec<u64>,
}

impl FusedT {
    /// Create from the chain's stages, in application order.
    pub fn new(stages: Vec<FusedStage>) -> FusedT {
        let n = stages.len();
        FusedT { stages, cur: Vec::new(), next: Vec::new(), stage_rows: vec![0; n] }
    }

    /// Per-stage output rows accumulated so far (tests).
    pub fn stage_rows(&self) -> &[u64] {
        &self.stage_rows
    }
}

impl Transformation for FusedT {
    fn open_out_bag(&mut self) {}

    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        // The pre-batching execution, kept verbatim as the element-path
        // reference: depth-first recursion, direct emits, NO per-stage
        // counting (the batch kernel is the counting path —
        // `record_observed` detects incomplete stage counts and falls
        // back to the lineage walk).
        run_stages(&self.stages, 0, v, &mut |x| out.emit(x));
    }

    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        if self.stages.is_empty() {
            let mut buf = vs.to_vec();
            out.emit_batch(&mut buf);
            return;
        }
        self.cur.clear();
        self.cur.extend_from_slice(vs);
        for (i, stage) in self.stages.iter().enumerate() {
            self.next.clear();
            match stage {
                FusedStage::Map(udf) => {
                    self.next.reserve(self.cur.len());
                    for v in &self.cur {
                        self.next.push(udf.call(v));
                    }
                }
                FusedStage::Filter(udf) => {
                    // Survivors are MOVED, not cloned (the element path
                    // clones every survivor out of the borrowed input).
                    for v in self.cur.drain(..) {
                        if udf.call(&v).as_bool() {
                            self.next.push(v);
                        }
                    }
                }
                FusedStage::FlatMap(udf) => {
                    for v in &self.cur {
                        self.next.extend(udf.call(v));
                    }
                }
            }
            self.stage_rows[i] += self.next.len() as u64;
            std::mem::swap(&mut self.cur, &mut self.next);
        }
        out.emit_batch(&mut self.cur);
    }

    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}

    fn take_stage_rows(&mut self) -> Option<Vec<u64>> {
        if self.stages.is_empty() {
            return None;
        }
        Some(std::mem::replace(&mut self.stage_rows, vec![0; self.stages.len()]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{Udf1, UdfN};
    use crate::ops::{run_once, run_once_chunked};

    fn i(v: i64) -> Value {
        Value::I64(v)
    }

    fn chain() -> Vec<FusedStage> {
        vec![
            FusedStage::Map(Udf1::new("x+1", |v: &Value| i(v.as_i64() + 1))),
            FusedStage::Filter(Udf1::new("even", |v: &Value| Value::Bool(v.as_i64() % 2 == 0))),
            FusedStage::Map(Udf1::new("x*10", |v: &Value| i(v.as_i64() * 10))),
        ]
    }

    #[test]
    fn fused_chain_matches_sequential_application() {
        let mut t = FusedT::new(chain());
        let out = run_once(&mut t, &[&[i(1), i(2), i(3), i(4)]]);
        // +1 -> [2,3,4,5]; keep even -> [2,4]; *10 -> [20,40].
        assert_eq!(out, vec![i(20), i(40)]);
    }

    #[test]
    fn flat_map_stage_expands_through_later_stages() {
        let stages = vec![
            FusedStage::FlatMap(UdfN::new("dup", |v: &Value| vec![v.clone(), v.clone()])),
            FusedStage::Map(Udf1::new("x+1", |v: &Value| i(v.as_i64() + 1))),
        ];
        let mut t = FusedT::new(stages);
        let out = run_once(&mut t, &[&[i(7)]]);
        assert_eq!(out, vec![i(8), i(8)]);
    }

    #[test]
    fn empty_stage_list_is_identity() {
        let mut t = FusedT::new(Vec::new());
        let out = run_once(&mut t, &[&[i(5)]]);
        assert_eq!(out, vec![i(5)]);
    }

    #[test]
    fn apply_stages_helper_agrees_with_operator() {
        let mut got = Vec::new();
        apply_stages(&chain(), &i(3), &mut |x| got.push(x));
        assert_eq!(got, vec![i(40)]);
    }

    #[test]
    fn batch_order_matches_depth_first_element_order() {
        // flatMap mid-chain: the batch loop runs breadth-first, the
        // apply_stages helper depth-first — outputs must align exactly.
        let stages = vec![
            FusedStage::Map(Udf1::new("x*2", |v: &Value| i(v.as_i64() * 2))),
            FusedStage::FlatMap(UdfN::new("span", |v: &Value| {
                vec![v.clone(), i(v.as_i64() + 1)]
            })),
            FusedStage::Filter(Udf1::new("not3", |v: &Value| Value::Bool(v.as_i64() % 3 != 0))),
        ];
        let input: Vec<Value> = (0..9).map(i).collect();
        let mut want = Vec::new();
        for v in &input {
            apply_stages(&stages, v, &mut |x| want.push(x));
        }
        let whole = run_once(&mut FusedT::new(stages.clone()), &[&input]);
        assert_eq!(whole, want);
        for chunk in [1usize, 2, 7] {
            let got = run_once_chunked(&mut FusedT::new(stages.clone()), &[&input], chunk);
            assert_eq!(got, want, "chunk={chunk}");
        }
    }

    #[test]
    fn stage_rows_count_interior_cardinalities() {
        let input = [i(1), i(2), i(3), i(4)];
        // Batch delivery: +1 -> 4 rows; keep even -> 2 rows; *10 -> 2.
        let mut t = FusedT::new(chain());
        let batched = run_once_chunked(&mut t, &[&input], 256);
        assert_eq!(t.stage_rows(), &[4, 2, 2]);
        // take_stage_rows drains the counters.
        assert_eq!(t.take_stage_rows(), Some(vec![4, 2, 2]));
        assert_eq!(t.stage_rows(), &[0, 0, 0]);
        // Element-at-a-time delivery is the UNCOUNTED legacy reference:
        // identical output, zero stage counts (`record_observed` detects
        // the incomplete counts and uses the lineage-walk fallback).
        let mut e = FusedT::new(chain());
        let element = run_once(&mut e, &[&input]);
        assert_eq!(element, batched);
        assert_eq!(e.stage_rows(), &[0, 0, 0]);
        // An empty chain has nothing to report.
        assert_eq!(FusedT::new(Vec::new()).take_stage_rows(), None);
    }
}
