//! The fused element-wise operator produced by `opt::fuse`: a maximal
//! linear chain of map/filter/flatMap stages executed inside one physical
//! operator instance. Each input element runs through the whole pipeline
//! before the next is touched, so fusing a k-stage chain removes k-1
//! per-element dispatches, k-1 intermediate bags per step, and all the
//! coordination messages (closes, conditional-output watchers) the
//! intermediate nodes would have cost.

use super::{Collector, Transformation};
use crate::frontend::FusedStage;
use crate::value::Value;

/// Run `v` through `stages[idx..]`, handing survivors to `emit`.
fn run_stages(stages: &[FusedStage], idx: usize, v: &Value, emit: &mut dyn FnMut(Value)) {
    let Some(stage) = stages.get(idx) else {
        emit(v.clone());
        return;
    };
    match stage {
        FusedStage::Map(udf) => run_stages(stages, idx + 1, &udf.call(v), emit),
        FusedStage::Filter(udf) => {
            if udf.call(v).as_bool() {
                run_stages(stages, idx + 1, v, emit);
            }
        }
        FusedStage::FlatMap(udf) => {
            for x in udf.call(v) {
                run_stages(stages, idx + 1, &x, emit);
            }
        }
    }
}

/// Apply a full stage pipeline to one element (shared with the baseline
/// interpreters so every executor agrees on fused semantics).
pub fn apply_stages(stages: &[FusedStage], v: &Value, emit: &mut dyn FnMut(Value)) {
    run_stages(stages, 0, v, emit);
}

/// Fused chain transformation (fully pipelined, stateless).
pub struct FusedT {
    stages: Vec<FusedStage>,
}

impl FusedT {
    /// Create from the chain's stages, in application order.
    pub fn new(stages: Vec<FusedStage>) -> FusedT {
        FusedT { stages }
    }
}

impl Transformation for FusedT {
    fn open_out_bag(&mut self) {}
    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        run_stages(&self.stages, 0, v, &mut |x| out.emit(x));
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{Udf1, UdfN};
    use crate::ops::run_once;

    fn i(v: i64) -> Value {
        Value::I64(v)
    }

    fn chain() -> Vec<FusedStage> {
        vec![
            FusedStage::Map(Udf1::new("x+1", |v: &Value| i(v.as_i64() + 1))),
            FusedStage::Filter(Udf1::new("even", |v: &Value| Value::Bool(v.as_i64() % 2 == 0))),
            FusedStage::Map(Udf1::new("x*10", |v: &Value| i(v.as_i64() * 10))),
        ]
    }

    #[test]
    fn fused_chain_matches_sequential_application() {
        let mut t = FusedT::new(chain());
        let out = run_once(&mut t, &[&[i(1), i(2), i(3), i(4)]]);
        // +1 -> [2,3,4,5]; keep even -> [2,4]; *10 -> [20,40].
        assert_eq!(out, vec![i(20), i(40)]);
    }

    #[test]
    fn flat_map_stage_expands_through_later_stages() {
        let stages = vec![
            FusedStage::FlatMap(UdfN::new("dup", |v: &Value| vec![v.clone(), v.clone()])),
            FusedStage::Map(Udf1::new("x+1", |v: &Value| i(v.as_i64() + 1))),
        ];
        let mut t = FusedT::new(stages);
        let out = run_once(&mut t, &[&[i(7)]]);
        assert_eq!(out, vec![i(8), i(8)]);
    }

    #[test]
    fn empty_stage_list_is_identity() {
        let mut t = FusedT::new(Vec::new());
        let out = run_once(&mut t, &[&[i(5)]]);
        assert_eq!(out, vec![i(5)]);
    }

    #[test]
    fn apply_stages_helper_agrees_with_operator() {
        let mut got = Vec::new();
        apply_stages(&chain(), &i(3), &mut |x| got.push(x));
        assert_eq!(got, vec![i(40)]);
    }
}
