//! Hash equi-join with build-side state reuse across iteration steps (§7).
//!
//! By default input 0 (the logical left) is the build side and input 1
//! the probe side; the `opt::joinside` pass can flip that choice through
//! [`HashJoinT::with_build`] when the cost model says the right side is
//! cheaper to build. Elements are `Pair(key, value)`; output elements are
//! always `Pair(key, Pair(left_value, right_value))` *regardless of which
//! side builds* — build-side selection is a physical-plan decision and
//! must be invisible to program semantics. Non-pair elements join on the
//! whole value with a `Unit` payload.
//!
//! When the build input is loop-invariant, the runtime omits re-pushing it
//! for subsequent output bags (`keeps_input_state(build) == true`) and the
//! hash table built once is probed by every iteration step — the paper's
//! headline optimization over Spark-style per-step jobs (§3.2.2, Fig. 8).

use super::state::MultiMap;
use super::{Collector, Transformation};
use crate::value::Value;
use rustc_hash::FxHashMap;

/// Split an element into its join key and payload: pairs key on their
/// first component, anything else keys on the whole value with a `Unit`
/// payload. (The `key` / `payload` lambda builtins mirror this, which is
/// what makes `opt::pushdown`'s predicate rewrites exact.)
pub fn key_and_payload(v: &Value) -> (Value, Value) {
    match v {
        Value::Pair(p) => (p.0.clone(), p.1.clone()),
        other => (other.clone(), Value::Unit),
    }
}

/// Streaming hash join (build side buffered, probe side pipelined once the
/// build is complete).
pub struct HashJoinT {
    /// The build table — [`MultiMap`] from the shared solution-set
    /// state vocabulary (`ops::state`). Not checkpointed: recovery
    /// rebuilds it from retained input buffers.
    table: MultiMap,
    /// Monomorphic i64-keyed build index, installed by [`typed_keys`]
    /// when `opt::types` proved both join keys `I64`: raw-integer
    /// hashing, no `Value` key clones on probe. Advisory — the first
    /// non-`I64` build key migrates the rows into the dynamic
    /// [`MultiMap`] and retires the fast path (invariant: while `Some`,
    /// `table` is empty).
    ///
    /// [`typed_keys`]: HashJoinT::typed_keys
    i64_table: Option<FxHashMap<i64, Vec<Value>>>,
    /// Remembers the `typed_keys` request so `drop_state` can re-arm
    /// the fast path for the next build bag even after a migration.
    typed: bool,
    build_done: bool,
    /// Probe elements that arrived before the build side closed.
    pending_probe: Vec<Value>,
    /// Which logical input builds the hash table (0 = left, 1 = right).
    build: usize,
    /// Join-result staging buffer reused across probe batches.
    buf: Vec<Value>,
    /// Number of probes served from a retained (reused) build table —
    /// reported by the engine's metrics to validate Fig. 8.
    pub reuse_probes: u64,
}

impl HashJoinT {
    /// Create an empty join with the default (left) build side.
    pub fn new() -> HashJoinT {
        HashJoinT::with_build(0)
    }

    /// Create an empty join building on logical input `build` (0 or 1).
    pub fn with_build(build: usize) -> HashJoinT {
        assert!(build <= 1, "join has two inputs");
        HashJoinT {
            table: MultiMap::new(),
            i64_table: None,
            typed: false,
            build_done: false,
            pending_probe: Vec::new(),
            build,
            buf: Vec::new(),
            reuse_probes: 0,
        }
    }

    /// Enable the monomorphic i64-key index. Only call when inference
    /// proved both inputs carry `I64` join keys; a stray non-`I64` build
    /// key still degrades gracefully to the dynamic table.
    pub fn typed_keys(mut self) -> HashJoinT {
        self.typed = true;
        self.i64_table = Some(FxHashMap::default());
        self
    }

    /// Build-table rows matching key `k`, from whichever index holds
    /// them. While the i64 index is live an `I64` key probes it directly
    /// and any other key rank matches nothing (the build side was proven
    /// all-`I64`, and `Value` equality never crosses ranks).
    fn matches_for(&self, k: &Value) -> Option<&[Value]> {
        match (&self.i64_table, k) {
            (Some(idx), Value::I64(ik)) => idx.get(ik).map(|r| r.as_slice()),
            (Some(_), _) => None,
            (None, _) => self.table.get(k),
        }
    }

    fn probe_into(&self, v: &Value, dst: &mut Vec<Value>) {
        let (k, pv) = key_and_payload(v);
        if let Some(matches) = self.matches_for(&k) {
            for bv in matches {
                // Emit in (left, right) order whichever side built.
                let (lv, rv) = if self.build == 0 {
                    (bv.clone(), pv.clone())
                } else {
                    (pv.clone(), bv.clone())
                };
                dst.push(Value::pair(k.clone(), Value::pair(lv, rv)));
            }
        }
    }

    fn probe(&self, v: &Value, out: &mut dyn Collector) {
        // Element-delivery twin of `probe_into`: emits matches directly
        // (no staging buffer — this path predates batching and must keep
        // its original cost profile).
        let (k, pv) = key_and_payload(v);
        if let Some(matches) = self.matches_for(&k) {
            for bv in matches {
                // Emit in (left, right) order whichever side built.
                let (lv, rv) = if self.build == 0 {
                    (bv.clone(), pv.clone())
                } else {
                    (pv.clone(), bv.clone())
                };
                out.emit(Value::pair(k.clone(), Value::pair(lv, rv)));
            }
        }
    }

    /// Probe everything buffered in `pending_probe` as one batch.
    fn flush_pending(&mut self, out: &mut dyn Collector) {
        if self.pending_probe.is_empty() {
            return;
        }
        let pending = std::mem::take(&mut self.pending_probe);
        let mut buf = std::mem::take(&mut self.buf);
        for v in &pending {
            self.probe_into(v, &mut buf);
        }
        out.emit_batch(&mut buf);
        self.buf = buf;
    }

    fn ingest_build(&mut self, v: &Value) {
        let (k, bv) = key_and_payload(v);
        if let Some(idx) = &mut self.i64_table {
            if let Value::I64(ik) = k {
                idx.entry(ik).or_default().push(bv);
                return;
            }
            // Inference was wrong about this bag: migrate the rows into
            // the dynamic table and retire the fast path for this build.
            for (mk, rows) in std::mem::take(idx) {
                for row in rows {
                    self.table.push(Value::I64(mk), row);
                }
            }
            self.i64_table = None;
        }
        self.table.push(k, bv);
    }
}

impl Default for HashJoinT {
    fn default() -> Self {
        Self::new()
    }
}

impl Transformation for HashJoinT {
    fn open_out_bag(&mut self) {
        self.pending_probe.clear();
        if self.build_done {
            self.reuse_probes += 1;
        }
    }

    fn push_in_element(&mut self, input: usize, v: &Value, out: &mut dyn Collector) {
        if input == self.build {
            self.ingest_build(v);
        } else if self.build_done {
            self.probe(v, out);
        } else {
            self.pending_probe.push(v.clone());
        }
    }

    fn push_in_batch(&mut self, input: usize, vs: &[Value], out: &mut dyn Collector) {
        if input == self.build {
            for v in vs {
                self.ingest_build(v);
            }
        } else if self.build_done {
            // Probe the whole batch into the staging buffer, emit once.
            let mut buf = std::mem::take(&mut self.buf);
            for v in vs {
                self.probe_into(v, &mut buf);
            }
            out.emit_batch(&mut buf);
            self.buf = buf;
        } else {
            self.pending_probe.extend_from_slice(vs);
        }
    }

    fn close_in_bag(&mut self, input: usize, out: &mut dyn Collector) {
        if input == self.build {
            self.build_done = true;
            self.flush_pending(out);
        }
    }

    fn close_out_bag(&mut self, out: &mut dyn Collector) {
        // If the probe side closed before the build side (possible under
        // adverse scheduling), flush now.
        if self.build_done {
            self.flush_pending(out);
        }
    }

    fn drop_state(&mut self, input: usize) {
        if input == self.build {
            self.table.clear();
            // Re-arm the fast path for the next build bag: even if a
            // stray key migrated this build, the next one may be clean.
            self.i64_table = self.typed.then(FxHashMap::default);
            self.build_done = false;
        }
    }

    fn keeps_input_state(&self, input: usize) -> bool {
        input == self.build
    }

    fn state_size(&self) -> Option<u64> {
        // Report the retained build table only once it is cross-bag
        // state (a reused build); a per-bag build is not solution-set
        // state and would distort the adaptive feedback.
        (self.build_done && self.reuse_probes > 0).then(|| {
            let typed_rows: u64 = self
                .i64_table
                .as_ref()
                .map_or(0, |idx| idx.values().map(|r| r.len() as u64).sum());
            self.table.rows() + typed_rows
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{run_once, VecCollector};

    fn kv(k: i64, v: i64) -> Value {
        Value::pair(Value::I64(k), Value::I64(v))
    }

    #[test]
    fn joins_matching_keys() {
        let mut j = HashJoinT::new();
        let out = run_once(&mut j, &[&[kv(1, 10), kv(2, 20)], &[kv(1, 100), kv(3, 300)]]);
        assert_eq!(out.len(), 1);
        assert_eq!(
            out[0],
            Value::pair(
                Value::I64(1),
                Value::pair(Value::I64(10), Value::I64(100))
            )
        );
    }

    #[test]
    fn duplicate_build_keys_multiply() {
        let mut j = HashJoinT::new();
        let out = run_once(&mut j, &[&[kv(1, 10), kv(1, 11)], &[kv(1, 100), kv(1, 101)]]);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn probe_before_build_close_is_buffered() {
        let mut j = HashJoinT::new();
        let mut out = VecCollector::default();
        j.open_out_bag();
        j.push_in_element(1, &kv(1, 100), &mut out); // early probe
        j.push_in_element(0, &kv(1, 10), &mut out);
        j.close_in_bag(0, &mut out); // flushes pending probe
        j.close_in_bag(1, &mut out);
        j.close_out_bag(&mut out);
        assert_eq!(out.items.len(), 1);
    }

    #[test]
    fn build_side_reused_across_bags() {
        let mut j = HashJoinT::new();
        let out1 = run_once(&mut j, &[&[kv(1, 10)], &[kv(1, 100)]]);
        assert_eq!(out1.len(), 1);
        // Next step: probe only (runtime reuses the build table).
        let mut out2 = VecCollector::default();
        j.open_out_bag();
        j.push_in_element(1, &kv(1, 200), &mut out2);
        j.close_in_bag(1, &mut out2);
        j.close_out_bag(&mut out2);
        assert_eq!(out2.items.len(), 1);
        assert_eq!(j.reuse_probes, 1);
    }

    #[test]
    fn drop_state_clears_table() {
        let mut j = HashJoinT::new();
        run_once(&mut j, &[&[kv(1, 10)], &[kv(1, 100)]]);
        j.drop_state(0);
        let out = run_once(&mut j, &[&[], &[kv(1, 100)]]);
        assert!(out.is_empty());
    }

    #[test]
    fn flipped_build_side_preserves_pair_order() {
        // Same inputs through both physical choices → identical output.
        let mut left_build = HashJoinT::new();
        let a = run_once(&mut left_build, &[&[kv(1, 10), kv(2, 20)], &[kv(1, 100)]]);
        let mut right_build = HashJoinT::with_build(1);
        let b = run_once(&mut right_build, &[&[kv(1, 10), kv(2, 20)], &[kv(1, 100)]]);
        let mut a = a;
        let mut b = b;
        a.sort();
        b.sort();
        assert_eq!(a, b);
        assert_eq!(
            a,
            vec![Value::pair(
                Value::I64(1),
                Value::pair(Value::I64(10), Value::I64(100))
            )]
        );
    }

    #[test]
    fn flipped_build_side_reuses_right_state() {
        let mut j = HashJoinT::with_build(1);
        // Build = input 1; probe = input 0.
        let out1 = run_once(&mut j, &[&[kv(1, 10)], &[kv(1, 100)]]);
        assert_eq!(out1.len(), 1);
        assert!(j.keeps_input_state(1));
        assert!(!j.keeps_input_state(0));
        // Next bag: only the probe (left) side is re-pushed.
        let mut out2 = VecCollector::default();
        j.open_out_bag();
        j.push_in_element(0, &kv(1, 20), &mut out2);
        j.close_in_bag(0, &mut out2);
        j.close_out_bag(&mut out2);
        assert_eq!(out2.items.len(), 1);
        assert_eq!(
            out2.items[0],
            Value::pair(Value::I64(1), Value::pair(Value::I64(20), Value::I64(100)))
        );
        assert_eq!(j.reuse_probes, 1);
        // Announcing a new build bag drops the table.
        j.drop_state(1);
        let out3 = run_once(&mut j, &[&[kv(1, 30)], &[]]);
        assert!(out3.is_empty());
    }

    #[test]
    fn batch_probe_agrees_with_element_delivery() {
        let build: Vec<Value> = (0..8).map(|k| kv(k, k * 10)).collect();
        let probe: Vec<Value> = (0..32).map(|x| kv(x % 8, x)).collect();
        let mut j = HashJoinT::new();
        let whole = run_once(&mut j, &[&build, &probe]);
        assert_eq!(whole.len(), 32);
        for chunk in [1usize, 3, 256] {
            let mut j = HashJoinT::new();
            let got = crate::ops::run_once_chunked(&mut j, &[&build, &probe], chunk);
            assert_eq!(got, whole, "chunk={chunk}");
        }
    }

    #[test]
    fn typed_index_agrees_with_dynamic_table() {
        let build: Vec<Value> = (0..8).map(|k| kv(k, k * 10)).collect();
        let probe: Vec<Value> = (0..32).map(|x| kv(x % 10, x)).collect();
        let mut dynamic = HashJoinT::new();
        let mut want = run_once(&mut dynamic, &[&build, &probe]);
        let mut typed = HashJoinT::new().typed_keys();
        let mut got = run_once(&mut typed, &[&build, &probe]);
        want.sort();
        got.sort();
        assert_eq!(got, want);
        // The fast path stayed live: every build key really was i64.
        assert!(typed.i64_table.is_some());
        assert!(typed.state_size().is_none()); // per-bag build, not reused
    }

    #[test]
    fn typed_index_migrates_on_non_i64_key_and_rearms() {
        // One string-keyed build row defeats the i64 layout; the rows
        // seen so far must migrate and the join stay exact.
        let build = vec![
            kv(1, 10),
            Value::pair(Value::str("k"), Value::I64(11)),
            kv(2, 20),
        ];
        let probe = vec![kv(1, 100), Value::pair(Value::str("k"), Value::I64(101))];
        let mut typed = HashJoinT::new().typed_keys();
        let mut got = run_once(&mut typed, &[&build, &probe]);
        assert!(typed.i64_table.is_none(), "fast path should have retired");
        let mut dynamic = HashJoinT::new();
        let mut want = run_once(&mut dynamic, &[&build, &probe]);
        got.sort();
        want.sort();
        assert_eq!(got, want);
        assert_eq!(got.len(), 2);
        // A new build bag re-arms the index.
        typed.drop_state(0);
        assert!(typed.i64_table.is_some());
        let out = run_once(&mut typed, &[&[kv(3, 30)], &[kv(3, 300)]]);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn scalar_elements_join_on_value() {
        let mut j = HashJoinT::new();
        let out = run_once(
            &mut j,
            &[&[Value::I64(5), Value::I64(6)], &[Value::I64(5)]],
        );
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], Value::pair(Value::I64(5), Value::pair(Value::Unit, Value::Unit)));
    }
}
