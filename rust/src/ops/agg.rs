//! Aggregations: reduceByKey, reduce, count, distinct. These are the
//! pipeline breakers (§9.1.2): they can only emit once their input bag is
//! complete (except `distinct`, which emits on first sight).

use super::state::{KeyedAcc, SetStore, StateSnapshot};
use super::{Collector, Transformation};
use crate::frontend::Udf2;
use crate::opt::types::TypedUdf2;
use crate::value::Value;

/// Combine two accumulator values through the compiled monomorphic
/// combiner when one is installed and the runtime variants match, else
/// through the dynamic UDF. The typed path skips the `Arc<dyn Fn>`
/// dispatch and the interpreter's environment bookkeeping per merge.
fn combine(typed: Option<&TypedUdf2>, udf: &Udf2, a: &Value, b: &Value) -> Value {
    match typed {
        Some(t) => t.combine(a, b).unwrap_or_else(|| udf.call(a, b)),
        None => udf.call(a, b),
    }
}

/// `reduceByKey`: combine `Pair(k, v)` values per key; emits
/// `Pair(k, acc)` at close (the grouped-aggregation example from §6.1).
///
/// In **delta mode** (`opt::delta`, `DeltaMode::AccReduce`) the
/// accumulator map persists across output bags — each superstep ingests
/// only the workset rows and emits only the keys whose accumulator
/// changed, the O(|changed|) circulation the incremental-iteration
/// engine is built on.
pub struct ReduceByKeyT {
    udf: Udf2,
    /// Compiled monomorphic combiner ([`crate::opt::types::compile_udf2`])
    /// for the inferred value type; per-merge variant checks fall back to
    /// `udf` so a wrong inference can only cost the fast path.
    typed: Option<TypedUdf2>,
    acc: KeyedAcc,
    delta: bool,
    /// Per-close emission staging buffer.
    buf: Vec<Value>,
}

impl ReduceByKeyT {
    /// Create from a combiner (full recompute per bag).
    pub fn new(udf: Udf2) -> ReduceByKeyT {
        ReduceByKeyT::with_typed(udf, None, false)
    }

    /// Create in delta mode: the accumulator persists across bags and
    /// only changed keys are emitted.
    pub fn new_delta(udf: Udf2) -> ReduceByKeyT {
        ReduceByKeyT::with_typed(udf, None, true)
    }

    /// Create with an optional compiled combiner (engine path, gated by
    /// `opt.columnar`); `delta` selects the persistent-accumulator mode.
    pub fn with_typed(udf: Udf2, typed: Option<TypedUdf2>, delta: bool) -> ReduceByKeyT {
        ReduceByKeyT { udf, typed, acc: KeyedAcc::new(), delta, buf: Vec::new() }
    }
}

impl ReduceByKeyT {
    fn ingest(&mut self, v: &Value) {
        let (k, pv) = match v {
            Value::Pair(p) => (p.0.clone(), p.1.clone()),
            other => panic!("reduceByKey expects pairs, got {other:?}"),
        };
        let (udf, typed) = (&self.udf, self.typed.as_ref());
        if self.delta {
            self.acc.merge_tracked(k, pv, |a, b| combine(typed, udf, a, b));
        } else {
            self.acc.merge(k, pv, |a, b| combine(typed, udf, a, b));
        }
    }
}

impl Transformation for ReduceByKeyT {
    fn open_out_bag(&mut self) {
        if !self.delta {
            self.acc.clear();
        }
    }
    fn push_in_element(&mut self, _input: usize, v: &Value, _out: &mut dyn Collector) {
        self.ingest(v);
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], _out: &mut dyn Collector) {
        for v in vs {
            self.ingest(v);
        }
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, out: &mut dyn Collector) {
        if self.delta {
            self.acc.take_changed(&mut self.buf);
        } else {
            self.acc.drain_all(&mut self.buf);
        }
        out.emit_batch(&mut self.buf);
    }
    fn state_size(&self) -> Option<u64> {
        self.delta.then(|| self.acc.len() as u64)
    }
    fn snapshot_state(&self) -> Option<StateSnapshot> {
        self.delta.then(|| self.acc.snapshot())
    }
    fn restore_state(&mut self, snap: &StateSnapshot) {
        if self.delta {
            self.acc.restore(snap);
        }
    }
    fn reset_state(&mut self) {
        self.acc.clear();
    }
}

/// `reduce`: full aggregation to (at most) one element, emitted at close.
/// An empty input emits nothing — the lifted-scalar consumer will fail
/// loudly rather than fabricate a value.
pub struct ReduceT {
    udf: Udf2,
    /// Compiled monomorphic combiner; same contract as
    /// [`ReduceByKeyT::typed`].
    typed: Option<TypedUdf2>,
    acc: Option<Value>,
}

impl ReduceT {
    /// Create from a combiner.
    pub fn new(udf: Udf2) -> ReduceT {
        ReduceT { udf, typed: None, acc: None }
    }

    /// Create with an optional compiled combiner (engine path, gated by
    /// `opt.columnar`).
    pub fn with_typed(udf: Udf2, typed: Option<TypedUdf2>) -> ReduceT {
        ReduceT { udf, typed, acc: None }
    }
}

impl Transformation for ReduceT {
    fn open_out_bag(&mut self) {
        self.acc = None;
    }
    fn push_in_element(&mut self, _input: usize, v: &Value, _out: &mut dyn Collector) {
        self.acc = Some(match self.acc.take() {
            Some(a) => combine(self.typed.as_ref(), &self.udf, &a, v),
            None => v.clone(),
        });
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], _out: &mut dyn Collector) {
        let mut acc = self.acc.take();
        for v in vs {
            acc = Some(match acc {
                Some(a) => combine(self.typed.as_ref(), &self.udf, &a, v),
                None => v.clone(),
            });
        }
        self.acc = acc;
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, out: &mut dyn Collector) {
        if let Some(a) = self.acc.take() {
            out.emit(a);
        }
    }
}

/// `count`: number of elements, as a one-element `I64` bag. Already the
/// ideal columnar citizen: the batch kernel reads only lengths, so the
/// typed data plane has nothing to add (no decode, no per-element work).
pub struct CountT {
    n: i64,
}

impl CountT {
    /// Create a zeroed counter.
    pub fn new() -> CountT {
        CountT { n: 0 }
    }
}

impl Default for CountT {
    fn default() -> Self {
        Self::new()
    }
}

impl Transformation for CountT {
    fn open_out_bag(&mut self) {
        self.n = 0;
    }
    fn push_in_element(&mut self, _input: usize, _v: &Value, _out: &mut dyn Collector) {
        self.n += 1;
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], _out: &mut dyn Collector) {
        // The batch interface at its best: counting costs O(1) per batch.
        self.n += vs.len() as i64;
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, out: &mut dyn Collector) {
        out.emit(Value::I64(self.n));
    }
}

/// `distinct`: emit each element on first occurrence (pipelined; relies on
/// hash partitioning to co-locate duplicates).
///
/// In **delta mode** (`opt::delta`, `DeltaMode::AccDistinct`) the
/// seen-set persists across output bags, so only *globally*-new
/// elements pass — the semi-naive frontier of the loop.
pub struct DistinctT {
    seen: SetStore,
    delta: bool,
    /// First-occurrence staging buffer reused across batches.
    buf: Vec<Value>,
}

impl DistinctT {
    /// Create an empty set (per-bag dedup).
    pub fn new() -> DistinctT {
        DistinctT { seen: SetStore::new(), delta: false, buf: Vec::new() }
    }

    /// Create in delta mode: the seen-set persists across bags.
    pub fn new_delta() -> DistinctT {
        DistinctT { seen: SetStore::new(), delta: true, buf: Vec::new() }
    }
}

impl Default for DistinctT {
    fn default() -> Self {
        Self::new()
    }
}

impl Transformation for DistinctT {
    fn open_out_bag(&mut self) {
        if !self.delta {
            self.seen.clear();
        }
    }
    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        if self.seen.insert(v) {
            out.emit(v.clone());
        }
    }
    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        for v in vs {
            if self.seen.insert(v) {
                self.buf.push(v.clone());
            }
        }
        out.emit_batch(&mut self.buf);
    }
    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}
    fn state_size(&self) -> Option<u64> {
        self.delta.then(|| self.seen.len() as u64)
    }
    fn snapshot_state(&self) -> Option<StateSnapshot> {
        self.delta.then(|| self.seen.snapshot())
    }
    fn restore_state(&mut self, snap: &StateSnapshot) {
        if self.delta {
            self.seen.restore(snap);
        }
    }
    fn reset_state(&mut self) {
        self.seen.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::run_once;

    fn kv(k: i64, v: i64) -> Value {
        Value::pair(Value::I64(k), Value::I64(v))
    }

    fn sum_udf() -> Udf2 {
        Udf2::new("+", |a, b| Value::I64(a.as_i64() + b.as_i64()))
    }

    #[test]
    fn reduce_by_key_sums_per_key() {
        let mut t = ReduceByKeyT::new(sum_udf());
        let mut out = run_once(&mut t, &[&[kv(1, 1), kv(2, 5), kv(1, 2)]]);
        out.sort();
        assert_eq!(out, vec![kv(1, 3), kv(2, 5)]);
    }

    #[test]
    fn reduce_folds_all() {
        let mut t = ReduceT::new(sum_udf());
        let out = run_once(&mut t, &[&[Value::I64(1), Value::I64(2), Value::I64(3)]]);
        assert_eq!(out, vec![Value::I64(6)]);
    }

    #[test]
    fn reduce_of_empty_emits_nothing() {
        let mut t = ReduceT::new(sum_udf());
        let out = run_once(&mut t, &[&[]]);
        assert!(out.is_empty());
    }

    #[test]
    fn count_counts() {
        let mut t = CountT::new();
        let out = run_once(&mut t, &[&[Value::I64(9), Value::I64(9)]]);
        assert_eq!(out, vec![Value::I64(2)]);
        // Bags are computed one at a time; counter resets.
        let out2 = run_once(&mut t, &[&[]]);
        assert_eq!(out2, vec![Value::I64(0)]);
    }

    #[test]
    fn distinct_deduplicates() {
        let mut t = DistinctT::new();
        let out = run_once(
            &mut t,
            &[&[Value::I64(1), Value::I64(1), Value::I64(2), Value::I64(1)]],
        );
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn state_resets_between_bags() {
        let mut t = ReduceByKeyT::new(sum_udf());
        let _ = run_once(&mut t, &[&[kv(1, 10)]]);
        let out = run_once(&mut t, &[&[kv(1, 1)]]);
        assert_eq!(out, vec![kv(1, 1)]);
    }

    #[test]
    fn delta_reduce_by_key_persists_and_emits_changed_only() {
        let mut t = ReduceByKeyT::new_delta(sum_udf());
        // First bag: everything is new, everything is emitted.
        let mut out = run_once(&mut t, &[&[kv(1, 1), kv(2, 5)]]);
        out.sort();
        assert_eq!(out, vec![kv(1, 1), kv(2, 5)]);
        // Second bag: accumulator persisted; only key 1 changes.
        let out2 = run_once(&mut t, &[&[kv(1, 2), kv(2, 0)]]);
        assert_eq!(out2, vec![kv(1, 3)]);
        assert_eq!(t.state_size(), Some(2));
        // Snapshot/restore reproduces the retained accumulator.
        let snap = t.snapshot_state().unwrap();
        let mut r = ReduceByKeyT::new_delta(sum_udf());
        r.restore_state(&snap);
        assert_eq!(r.snapshot_state().unwrap(), snap);
        // Reset drops it.
        t.reset_state();
        assert_eq!(t.state_size(), Some(0));
    }

    #[test]
    fn delta_distinct_emits_globally_new_only() {
        let mut t = DistinctT::new_delta();
        let out = run_once(&mut t, &[&[Value::I64(1), Value::I64(2), Value::I64(1)]]);
        assert_eq!(out.len(), 2);
        // Second bag: 1 and 2 were seen in the previous bag.
        let out2 = run_once(&mut t, &[&[Value::I64(1), Value::I64(2), Value::I64(3)]]);
        assert_eq!(out2, vec![Value::I64(3)]);
        assert_eq!(t.state_size(), Some(3));
        let snap = t.snapshot_state().unwrap();
        let mut r = DistinctT::new_delta();
        r.restore_state(&snap);
        let out3 = run_once(&mut r, &[&[Value::I64(3), Value::I64(4)]]);
        assert_eq!(out3, vec![Value::I64(4)]);
    }

    fn parsed_udf2(src: &str) -> Udf2 {
        use crate::frontend::{ast, interp_expr, lexer::lex, parser};
        let ast = parser::parse(&lex(&format!("x = {src};")).unwrap()).unwrap();
        match &ast.stmts[0] {
            ast::Stmt::Assign(_, ast::Expr::Lambda(ps, body)) => {
                interp_expr::compile_udf2(ps.clone(), (**body).clone(), "t".into()).unwrap()
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn typed_combiner_agrees_with_dynamic_and_falls_back() {
        use crate::opt::types::compile_udf2;
        use crate::value::ElemType;
        let udf = parsed_udf2("|a, b| a + b");
        let typed = compile_udf2(&udf, &ElemType::I64);
        assert!(typed.is_some(), "i64 sum compiles");
        let input: Vec<Value> = (0..17).map(|x| kv(x % 3, x)).collect();
        let mut dynamic = run_once(&mut ReduceByKeyT::new(udf.clone()), &[&input]);
        dynamic.sort();
        let mut typed_out =
            run_once(&mut ReduceByKeyT::with_typed(udf.clone(), typed.clone(), false), &[&input]);
        typed_out.sort();
        assert_eq!(typed_out, dynamic);
        // Delta mode threads the same compiled combiner.
        let mut d = ReduceByKeyT::with_typed(udf.clone(), typed.clone(), true);
        let mut first = run_once(&mut d, &[&input]);
        first.sort();
        assert_eq!(first, dynamic);
        // Runtime values defeating the compiled type (strings) fall back
        // to the dynamic UDF — `+` concatenates, nothing panics.
        let strs = [
            Value::pair(Value::I64(1), Value::str("a")),
            Value::pair(Value::I64(1), Value::str("b")),
        ];
        let out = run_once(&mut ReduceByKeyT::with_typed(udf.clone(), typed.clone(), false), &[&strs]);
        assert_eq!(out, vec![Value::pair(Value::I64(1), Value::str("ab"))]);
        // ReduceT threads it too.
        let nums: Vec<Value> = (0..9).map(Value::I64).collect();
        assert_eq!(
            run_once(&mut ReduceT::with_typed(udf.clone(), typed), &[&nums]),
            run_once(&mut ReduceT::new(udf), &[&nums]),
        );
    }

    #[test]
    fn batch_ingest_agrees_with_element_delivery() {
        // Every aggregation's batch kernel must match `run_once`'s
        // element-at-a-time delivery at every chunk size.
        let input: Vec<Value> = (0..23).map(|x| kv(x % 5, x)).collect();
        let scalars: Vec<Value> = (0..23).map(|x| Value::I64(x % 5)).collect();
        let make: [(&str, fn() -> Box<dyn crate::ops::Transformation>, bool); 4] = [
            ("reduceByKey", || Box::new(ReduceByKeyT::new(sum_udf())), true),
            ("reduce", || Box::new(ReduceT::new(sum_udf())), false),
            ("count", || Box::new(CountT::new()), false),
            ("distinct", || Box::new(DistinctT::new()), false),
        ];
        for (name, mk, keyed) in make {
            let bag: &[Value] = if keyed { &input } else { &scalars };
            let mut element = run_once(mk().as_mut(), &[bag]);
            element.sort();
            for chunk in [1usize, 2, 7, 256] {
                let mut got = crate::ops::run_once_chunked(mk().as_mut(), &[bag], chunk);
                got.sort();
                assert_eq!(got, element, "{name} chunk={chunk}");
            }
        }
    }
}
