//! The delta-Φ transformation: the solution-set anchor of a
//! delta-incremental loop (see `docs/incremental.md`).
//!
//! A loop-header Φ in delta mode no longer passes full bags through.
//! Each arriving bag is a *delta* (the init bag on loop entry, then the
//! back-edge operator's changed rows per superstep) merged into an
//! indexed solution set held across supersteps:
//!
//! * **Upsert** (re-aggregation loops, back edge = reduceByKey): the
//!   store keys rows by `Value::key()`; a changed key's arriving rows
//!   replace its previous rows. Downstream (in-loop) consumers receive
//!   the arriving rows only on the *init* bag — afterwards the
//!   reduceByKey's retained accumulator already contains them, and
//!   re-circulating would double-count.
//! * **Frontier** (semi-naive loops, back edge = distinct): arriving
//!   rows are the per-step frontier, always re-emitted downstream; the
//!   store accumulates their union.
//!
//! Exit edges (consumers outside the loop) are handled by the engine:
//! it calls [`crate::ops::Transformation::materialize_state`] at
//! send-decision time instead of forwarding the per-step delta.

use super::state::{FrontierStore, KeyedStore, StateSnapshot};
use super::{Collector, Transformation};
use crate::value::Value;

enum Store {
    Upsert(KeyedStore),
    Frontier(FrontierStore),
}

/// Loop-header Φ holding an indexed solution set across supersteps.
pub struct DeltaPhiT {
    store: Store,
    /// Whether the current bag's elements are re-emitted downstream.
    emit: bool,
    /// Frontier only: whether the current bag is the raw init bag.
    init_bag: bool,
    /// Emission staging buffer reused across batches.
    buf: Vec<Value>,
}

impl DeltaPhiT {
    /// Upsert-store Φ (re-aggregation loops).
    pub fn upsert() -> DeltaPhiT {
        DeltaPhiT {
            store: Store::Upsert(KeyedStore::new()),
            emit: false,
            init_bag: false,
            buf: Vec::new(),
        }
    }

    /// Frontier-store Φ (semi-naive loops).
    pub fn frontier() -> DeltaPhiT {
        DeltaPhiT {
            store: Store::Frontier(FrontierStore::new()),
            emit: true,
            init_bag: false,
            buf: Vec::new(),
        }
    }

    fn absorb(&mut self, v: &Value) {
        match &mut self.store {
            Store::Upsert(s) => s.upsert(v),
            Store::Frontier(f) => {
                if self.init_bag {
                    f.push_raw(v);
                } else {
                    f.insert(v);
                }
            }
        }
    }
}

impl Transformation for DeltaPhiT {
    fn open_out_bag(&mut self) {
        match &mut self.store {
            Store::Upsert(s) => {
                // Re-emit only the init bag: afterwards the loop's
                // retained accumulator supersedes re-ingestion.
                self.emit = s.begin_bag();
                self.init_bag = self.emit;
            }
            Store::Frontier(f) => {
                self.init_bag = f.begin_bag();
                self.emit = true;
            }
        }
    }

    fn push_in_element(&mut self, _input: usize, v: &Value, out: &mut dyn Collector) {
        self.absorb(v);
        if self.emit {
            out.emit(v.clone());
        }
    }

    fn push_in_batch(&mut self, _input: usize, vs: &[Value], out: &mut dyn Collector) {
        for v in vs {
            self.absorb(v);
        }
        if self.emit {
            self.buf.extend_from_slice(vs);
            out.emit_batch(&mut self.buf);
        }
    }

    fn close_in_bag(&mut self, _input: usize, _out: &mut dyn Collector) {}
    fn close_out_bag(&mut self, _out: &mut dyn Collector) {}

    fn state_size(&self) -> Option<u64> {
        Some(match &self.store {
            Store::Upsert(s) => s.rows(),
            Store::Frontier(f) => f.rows(),
        })
    }

    fn snapshot_state(&self) -> Option<StateSnapshot> {
        Some(match &self.store {
            Store::Upsert(s) => s.snapshot(),
            Store::Frontier(f) => f.snapshot(),
        })
    }

    fn restore_state(&mut self, snap: &StateSnapshot) {
        match &mut self.store {
            Store::Upsert(s) => s.restore(snap),
            Store::Frontier(f) => f.restore(snap),
        }
    }

    fn reset_state(&mut self) {
        match &mut self.store {
            Store::Upsert(s) => s.reset(),
            Store::Frontier(f) => f.reset(),
        }
    }

    fn materialize_state(&self, out: &mut Vec<Value>) {
        match &self.store {
            Store::Upsert(s) => s.materialize(out),
            Store::Frontier(f) => f.materialize(out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::VecCollector;

    fn kv(k: i64, v: i64) -> Value {
        Value::pair(Value::I64(k), Value::I64(v))
    }

    fn feed(t: &mut DeltaPhiT, items: &[Value]) -> Vec<Value> {
        let mut out = VecCollector::default();
        t.open_out_bag();
        t.push_in_batch(0, items, &mut out);
        t.close_in_bag(0, &mut out);
        t.close_out_bag(&mut out);
        out.items
    }

    #[test]
    fn upsert_phi_emits_init_bag_only_and_upserts_later_deltas() {
        let mut t = DeltaPhiT::upsert();
        // Init bag re-emitted (the loop's accumulator is still empty).
        let e1 = feed(&mut t, &[kv(1, 10), kv(2, 20)]);
        assert_eq!(e1, vec![kv(1, 10), kv(2, 20)]);
        // Later deltas are merged silently.
        let e2 = feed(&mut t, &[kv(1, 11)]);
        assert!(e2.is_empty());
        let mut full = Vec::new();
        t.materialize_state(&mut full);
        full.sort();
        assert_eq!(full, vec![kv(1, 11), kv(2, 20)]);
        assert_eq!(t.state_size(), Some(2));
    }

    #[test]
    fn frontier_phi_always_emits_and_accumulates_union() {
        let mut t = DeltaPhiT::frontier();
        let e1 = feed(&mut t, &[Value::I64(1)]);
        assert_eq!(e1, vec![Value::I64(1)]);
        // The next frontier re-includes 1 (the back-edge distinct sees
        // init elements for the first time); the store dedups it.
        let e2 = feed(&mut t, &[Value::I64(1), Value::I64(2)]);
        assert_eq!(e2, vec![Value::I64(1), Value::I64(2)]);
        let mut full = Vec::new();
        t.materialize_state(&mut full);
        full.sort();
        assert_eq!(full, vec![Value::I64(1), Value::I64(2)]);
    }

    #[test]
    fn snapshot_restore_roundtrips_mid_loop() {
        let mut t = DeltaPhiT::upsert();
        feed(&mut t, &[kv(1, 10)]);
        feed(&mut t, &[kv(1, 12)]);
        let snap = t.snapshot_state().unwrap();
        let mut r = DeltaPhiT::upsert();
        r.restore_state(&snap);
        assert_eq!(r.snapshot_state().unwrap(), snap);
        // Restored Φ is past its init bag: deltas stay silent.
        let e = feed(&mut r, &[kv(1, 13)]);
        assert!(e.is_empty());
    }

    #[test]
    fn reset_rearms_init_emission() {
        let mut t = DeltaPhiT::upsert();
        feed(&mut t, &[kv(1, 10)]);
        feed(&mut t, &[kv(1, 12)]);
        t.reset_state();
        assert_eq!(t.state_size(), Some(0));
        let e = feed(&mut t, &[kv(5, 50)]);
        assert_eq!(e, vec![kv(5, 50)]);
    }
}
