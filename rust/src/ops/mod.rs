//! The bag-transformation interface (§6.1) and its implementations.
//!
//! Transformations are *control-flow oblivious*: they compute one output
//! bag at a time from the input bags the runtime feeds them. All control
//! flow — which bags to compute, which input bags to use, where to send
//! outputs — is the coordination runtime's job (`coord`, `exec`).
//!
//! The interface mirrors the paper:
//! * `open_out_bag` — start computing a new output bag (reset per-bag
//!   state);
//! * `push_in_element(input, v, out)` — one element of the current input
//!   bag on logical input `input`;
//! * `push_in_batch(input, vs, out)` — a whole batch of elements at once.
//!   The engine's hot path: the default forwards to the element method
//!   (so exotic operators stay correct with zero changes), and the hot
//!   operators override it with tight loops that stage into reusable
//!   buffers and emit once per batch instead of once per element;
//! * `close_in_bag(input, out)` — no more elements on that input;
//! * `close_out_bag(out)` — all inputs closed; emit any finals;
//! * `drop_state(input)` — §7 extension: the runtime announces that the
//!   bag on `input` *will change* for the next output bag, so state built
//!   for it (e.g. a hash-join build table) must be dropped. Absent this
//!   call, a transformation with `keeps_input_state(input) == true` may
//!   assume the same input bag is reused and will NOT be re-pushed.
//!
//! Batch and element delivery are interchangeable: pushing a bag as one
//! batch, element by element, or any split in between must produce the
//! same output bag (the property suite runs the engine at batch sizes
//! {1, 2, 7, 256} to pin this).

pub mod agg;
pub mod basic;
pub mod delta;
pub mod fused;
pub mod io;
pub mod join;
pub mod state;
pub mod xla;

use crate::dataflow::{DeltaMode, Node};
use crate::error::Result;
use crate::frontend::Rhs;
use crate::value::{ElemType, Value};
use std::sync::Arc;

/// Output collector handed to transformations (§6.1: `Emit`; bag closing
/// is driven by the runtime, which knows when all inputs are done).
pub trait Collector {
    /// Emit one element of the current output bag.
    fn emit(&mut self, v: Value);
    /// Emit a whole batch, draining `vs` (its allocation stays with the
    /// caller for reuse across batches). One virtual call per batch
    /// instead of one per element; the default loops over [`Collector::emit`].
    fn emit_batch(&mut self, vs: &mut Vec<Value>) {
        for v in vs.drain(..) {
            self.emit(v);
        }
    }
    /// Emit a whole columnar batch (typed kernels). The default decodes
    /// to `Value`s and forwards to [`Collector::emit_batch`]; the
    /// engine's staging collector overrides it to derive routing key
    /// hashes column-at-a-time before decoding.
    fn emit_columns(&mut self, cols: crate::bag::ColumnBatch) {
        let mut vs = cols.into_values();
        self.emit_batch(&mut vs);
    }
}

/// A growable vector collector (tests, single-threaded baseline, and the
/// engine's per-bag staging buffer).
#[derive(Default, Debug)]
pub struct VecCollector {
    /// Collected elements.
    pub items: Vec<Value>,
}

impl Collector for VecCollector {
    fn emit(&mut self, v: Value) {
        self.items.push(v);
    }
    fn emit_batch(&mut self, vs: &mut Vec<Value>) {
        self.items.append(vs);
    }
}

/// A bag-transformation (one physical instance's compute logic).
pub trait Transformation: Send {
    /// Start a new output bag.
    fn open_out_bag(&mut self);
    /// Receive one input element on logical input `input`.
    fn push_in_element(&mut self, input: usize, v: &Value, out: &mut dyn Collector);
    /// Receive a batch of input elements on logical input `input`. The
    /// engine's data plane delivers everything through this method;
    /// splitting a bag into batches differently must not change the
    /// output. Default: the element loop (correct for every operator);
    /// hot operators override it with vectorized kernels.
    fn push_in_batch(&mut self, input: usize, vs: &[Value], out: &mut dyn Collector) {
        for v in vs {
            self.push_in_element(input, v, out);
        }
    }
    /// The current bag on logical input `input` is complete.
    fn close_in_bag(&mut self, input: usize, out: &mut dyn Collector);
    /// All inputs are complete: emit any remaining output.
    fn close_out_bag(&mut self, out: &mut dyn Collector);
    /// §7: the bag on `input` will differ for the next output bag.
    fn drop_state(&mut self, _input: usize) {}
    /// §7: true if this transformation retains per-input state across
    /// output bags (so the runtime may skip re-pushing an unchanged input).
    fn keeps_input_state(&self, _input: usize) -> bool {
        false
    }
    /// 0-input sources generate their output here (called between open and
    /// close by the runtime).
    fn generate(&mut self, _out: &mut dyn Collector) {}
    /// Per-stage output row counts accumulated since the last call, for
    /// operators that run an interior pipeline ([`fused::FusedT`]).
    /// `None` for everything else. The engine polls this once per
    /// completed bag and folds the counts into the per-node metrics
    /// (`stage_rows`), which is what lets adaptive re-optimization pin
    /// interior filter/flatMap cardinalities that the fused tail's own
    /// output count cannot reveal.
    fn take_stage_rows(&mut self) -> Option<Vec<u64>> {
        None
    }
    /// Rows a batch kernel consumed directly from the borrowed input —
    /// no upfront clone of the whole batch ([`fused::FusedT`]'s stage-0
    /// borrow and its columnar pipeline). Drained (reset to 0) per call;
    /// the engine folds it into the `exec.fused_borrowed_rows` counter.
    fn take_borrowed_rows(&mut self) -> u64 {
        0
    }
    /// Rows of cross-superstep solution-set state currently held
    /// (delta-mode operators); `None` for stateless / full-recompute
    /// operators. Folded into `NodeRows::state_size` so adaptive
    /// re-optimization and `obs::` spans see solution-set size, not
    /// just the (small) per-step delta row counts.
    fn state_size(&self) -> Option<u64> {
        None
    }
    /// Canonical snapshot of cross-superstep state for
    /// `exec::recovery` checkpoints. `None` for operators whose state
    /// is rebuilt from retained input buffers (e.g. hash-join builds)
    /// or who hold none.
    fn snapshot_state(&self) -> Option<state::StateSnapshot> {
        None
    }
    /// Restore cross-superstep state from a checkpoint snapshot.
    fn restore_state(&mut self, _snap: &state::StateSnapshot) {}
    /// Drop cross-superstep state (the execution path left the delta
    /// loop; a later re-entry starts fresh).
    fn reset_state(&mut self) {}
    /// Append the full materialized solution set to `out` (delta-Φ
    /// exit edges: consumers outside the loop receive the solution
    /// set, not the per-step delta).
    fn materialize_state(&self, _out: &mut Vec<Value>) {}
}

/// Instance context given to the factory: which physical instance this is
/// and how many exist (sources partition their data by it), plus the
/// inferred element types and columnar gate the typed kernels key off.
#[derive(Clone)]
pub struct MakeCtx {
    /// This instance's index within the logical node.
    pub inst: usize,
    /// Number of physical instances of the logical node.
    pub insts: usize,
    /// Named in-memory datasets (see [`crate::workload::registry`]).
    pub registry: Arc<crate::workload::registry::Registry>,
    /// Base directory for `readFile` / `writeFile` paths.
    pub io_dir: std::path::PathBuf,
    /// Inferred element type of each logical input (parallel to the
    /// node's input edges; missing entries mean [`ElemType::Dyn`]).
    pub in_types: Vec<ElemType>,
    /// Inferred element type of this node's output.
    pub out_type: ElemType,
    /// Install typed columnar kernels? The graph's `opt.columnar` gate
    /// resolved against the engine's batch size (`ColumnarGate::enabled`);
    /// `false` keeps every operator on the dynamic `Value` path.
    pub columnar: bool,
}

impl Default for MakeCtx {
    fn default() -> Self {
        MakeCtx {
            inst: 0,
            insts: 1,
            registry: crate::workload::registry::global(),
            io_dir: std::path::PathBuf::from("."),
            in_types: Vec::new(),
            out_type: ElemType::Dyn,
            columnar: false,
        }
    }
}

impl MakeCtx {
    /// The inferred element type of logical input `i` (`Dyn` when the
    /// optimizer did not run or inference gave up).
    pub fn in_type(&self, i: usize) -> ElemType {
        self.in_types.get(i).cloned().unwrap_or(ElemType::Dyn)
    }
}

/// Join-key type of an input element type, mirroring
/// [`join::key_and_payload`]: pairs key on their first component,
/// anything else keys on the whole value.
fn join_key_type(t: &ElemType) -> ElemType {
    match t {
        ElemType::Pair(k, _) => (**k).clone(),
        other => other.clone(),
    }
}

/// Typed combiner for a keyed reduce: the operand type is the *value*
/// component of the input pair type. `None` (dynamic path) when the
/// columnar gate is off or the input is not a concretely typed pair.
fn typed_combiner(
    ctx: &MakeCtx,
    udf: &crate::frontend::Udf2,
) -> Option<crate::opt::types::TypedUdf2> {
    if !ctx.columnar {
        return None;
    }
    match ctx.in_type(0) {
        ElemType::Pair(_, v) => crate::opt::types::compile_udf2(udf, &v),
        _ => None,
    }
}

/// Instantiate the transformation for a dataflow node, honoring both
/// the plan's hash-join build-side choice and the `opt::delta`
/// annotation. The entry point for operator construction on the
/// engine's path.
pub fn make_node(
    node: &Node,
    join_build: usize,
    ctx: &MakeCtx,
) -> Result<Box<dyn Transformation>> {
    if let Some(spec) = &node.delta {
        match spec.mode {
            DeltaMode::PhiUpsert => return Ok(Box::new(delta::DeltaPhiT::upsert())),
            DeltaMode::PhiFrontier => return Ok(Box::new(delta::DeltaPhiT::frontier())),
            DeltaMode::AccReduce => {
                if let Rhs::ReduceByKey { udf, .. } = &node.op {
                    return Ok(Box::new(agg::ReduceByKeyT::with_typed(
                        udf.clone(),
                        typed_combiner(ctx, udf),
                        true,
                    )));
                }
                return Err(crate::Error::Dataflow(format!(
                    "AccReduce delta mode on non-reduceByKey node '{}'",
                    node.name
                )));
            }
            DeltaMode::AccDistinct => {
                if !matches!(node.op, Rhs::Distinct { .. }) {
                    return Err(crate::Error::Dataflow(format!(
                        "AccDistinct delta mode on non-distinct node '{}'",
                        node.name
                    )));
                }
                return Ok(Box::new(agg::DistinctT::new_delta()));
            }
        }
    }
    make_with_join_build(&node.op, join_build, ctx)
}

/// Instantiate the transformation for a logical operation, honoring the
/// plan's choice of hash-join build input (`opt::joinside` annotation;
/// 0 — the left input — is the §5.3 default).
pub fn make_with_join_build(
    op: &Rhs,
    join_build: usize,
    ctx: &MakeCtx,
) -> Result<Box<dyn Transformation>> {
    match op {
        Rhs::Join { .. } => {
            let mut j = join::HashJoinT::with_build(join_build);
            if ctx.columnar
                && join_key_type(&ctx.in_type(0)) == ElemType::I64
                && join_key_type(&ctx.in_type(1)) == ElemType::I64
            {
                j = j.typed_keys();
            }
            Ok(Box::new(j))
        }
        _ => make(op, ctx),
    }
}

/// Instantiate the transformation for a logical operation.
pub fn make(op: &Rhs, ctx: &MakeCtx) -> Result<Box<dyn Transformation>> {
    Ok(match op {
        Rhs::BagLit(items) => Box::new(io::BagLitT::new(items.clone(), ctx)),
        Rhs::NamedSource(name) => Box::new(io::NamedSourceT::new(name.clone(), ctx)),
        Rhs::ReadFile { .. } => Box::new(io::ReadFileT::new(ctx)),
        Rhs::WriteFile { .. } => Box::new(io::WriteFileT::new(ctx)),
        Rhs::Collect { .. } => Box::new(basic::PassThroughT::default()),
        Rhs::Map { udf, .. } => {
            let typed = ctx
                .columnar
                .then(|| crate::opt::types::compile_udf1(udf, &ctx.in_type(0)))
                .flatten();
            Box::new(basic::MapT::with_typed(udf.clone(), typed))
        }
        Rhs::Filter { udf, .. } => {
            let typed = ctx
                .columnar
                .then(|| crate::opt::types::compile_udf1(udf, &ctx.in_type(0)))
                .flatten();
            Box::new(basic::FilterT::with_typed(udf.clone(), typed))
        }
        Rhs::FlatMap { udf, .. } => Box::new(basic::FlatMapT::new(udf.clone())),
        Rhs::Join { .. } => return make_with_join_build(op, 0, ctx),
        Rhs::ReduceByKey { udf, .. } => Box::new(agg::ReduceByKeyT::with_typed(
            udf.clone(),
            typed_combiner(ctx, udf),
            false,
        )),
        Rhs::Reduce { udf, .. } => {
            let typed = ctx
                .columnar
                .then(|| crate::opt::types::compile_udf2(udf, &ctx.in_type(0)))
                .flatten();
            Box::new(agg::ReduceT::with_typed(udf.clone(), typed))
        }
        Rhs::Count { .. } => Box::new(agg::CountT::new()),
        Rhs::Distinct { .. } => Box::new(agg::DistinctT::new()),
        Rhs::Union { .. } => Box::new(basic::UnionT::default()),
        Rhs::Cross { .. } => Box::new(basic::CrossT::new()),
        Rhs::Phi(_) => Box::new(basic::PhiT::default()),
        Rhs::Fused { stages, .. } => {
            let typed = ctx
                .columnar
                .then(|| {
                    let in_ty = ctx.in_type(0);
                    crate::opt::types::compile_chain(stages, &in_ty)
                        .map(|(s, _)| fused::TypedChain { in_ty, stages: s })
                })
                .flatten();
            Box::new(fused::FusedT::with_typed(stages.clone(), typed))
        }
        Rhs::XlaCall { spec, .. } => Box::new(xla::XlaCallT::new(spec.clone())),
        Rhs::Const(_) | Rhs::Copy(_) | Rhs::ScalarUn { .. } | Rhs::ScalarBin { .. } => {
            return Err(crate::Error::Dataflow(format!(
                "operation {} should have been removed by SSA/lifting",
                op.mnemonic()
            )))
        }
    })
}

/// Test/baseline helper: run a transformation over fully materialized
/// input bags and return the output bag. Delivery is deliberately
/// **element-at-a-time**: the baseline interpreters built on this stay an
/// independent implementation of operator semantics, so every
/// engine-vs-oracle differential test doubles as a batched-vs-element
/// agreement check (the engine's data plane uses `push_in_batch`).
pub fn run_once(t: &mut dyn Transformation, inputs: &[&[Value]]) -> Vec<Value> {
    let mut out = VecCollector::default();
    t.open_out_bag();
    if inputs.is_empty() {
        t.generate(&mut out);
    } else {
        for (i, bag) in inputs.iter().enumerate() {
            for v in bag.iter() {
                t.push_in_element(i, v, &mut out);
            }
            t.close_in_bag(i, &mut out);
        }
    }
    t.close_out_bag(&mut out);
    out.items
}

/// [`run_once`] delivering every bag through `push_in_batch` in chunks of
/// `chunk` elements — exercises the batch kernels and their boundaries
/// (tests assert it agrees with [`run_once`]'s element delivery at every
/// chunk size).
pub fn run_once_chunked(
    t: &mut dyn Transformation,
    inputs: &[&[Value]],
    chunk: usize,
) -> Vec<Value> {
    let chunk = chunk.max(1);
    let mut out = VecCollector::default();
    t.open_out_bag();
    if inputs.is_empty() {
        t.generate(&mut out);
    } else {
        for (i, bag) in inputs.iter().enumerate() {
            for part in bag.chunks(chunk) {
                t.push_in_batch(i, part, &mut out);
            }
            t.close_in_bag(i, &mut out);
        }
    }
    t.close_out_bag(&mut out);
    out.items
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{Udf1, UdfN};

    #[test]
    fn factory_covers_all_runtime_ops() {
        let ctx = MakeCtx::default();
        let ops: Vec<Rhs> = vec![
            Rhs::BagLit(vec![Value::I64(1)]),
            Rhs::NamedSource("x".into()),
            Rhs::ReadFile { name: 0 },
            Rhs::WriteFile { data: 0, name: 1 },
            Rhs::Collect { input: 0, label: "l".into() },
            Rhs::Map { input: 0, udf: Udf1::new("id", |v: &Value| v.clone()) },
            Rhs::Filter { input: 0, udf: Udf1::new("t", |_| Value::Bool(true)) },
            Rhs::FlatMap { input: 0, udf: UdfN::new("one", |v: &Value| vec![v.clone()]) },
            Rhs::Join { left: 0, right: 1 },
            Rhs::ReduceByKey {
                input: 0,
                udf: crate::frontend::Udf2::new("+", |a, b| {
                    Value::I64(a.as_i64() + b.as_i64())
                }),
            },
            Rhs::Reduce {
                input: 0,
                udf: crate::frontend::Udf2::new("+", |a, b| {
                    Value::I64(a.as_i64() + b.as_i64())
                }),
            },
            Rhs::Count { input: 0 },
            Rhs::Distinct { input: 0 },
            Rhs::Union { left: 0, right: 1 },
            Rhs::Cross { left: 0, right: 1 },
            Rhs::Phi(vec![(0, 0), (1, 1)]),
            Rhs::Fused {
                input: 0,
                stages: vec![crate::frontend::FusedStage::Map(Udf1::new("id", |v: &Value| {
                    v.clone()
                }))],
                lineage: vec!["id".into()],
            },
        ];
        for op in &ops {
            assert!(make(op, &ctx).is_ok(), "{}", op.mnemonic());
        }
        // Compiled-away ops are rejected.
        assert!(make(&Rhs::Const(Value::I64(1)), &ctx).is_err());
        assert!(make(&Rhs::Copy(0), &ctx).is_err());
    }

    #[test]
    fn factory_honors_join_build_side() {
        let ctx = MakeCtx::default();
        // Build on input 1: the right element (input 1) is buffered, the
        // left (input 0) probes — output keeps (left, right) order.
        let mut t =
            make_with_join_build(&Rhs::Join { left: 0, right: 1 }, 1, &ctx).unwrap();
        assert!(t.keeps_input_state(1));
        assert!(!t.keeps_input_state(0));
        let out = run_once(
            t.as_mut(),
            &[
                &[Value::pair(Value::I64(1), Value::str("L"))],
                &[Value::pair(Value::I64(1), Value::str("R"))],
            ],
        );
        assert_eq!(
            out,
            vec![Value::pair(
                Value::I64(1),
                Value::pair(Value::str("L"), Value::str("R"))
            )]
        );
        // Non-joins pass through to the plain factory.
        assert!(make_with_join_build(&Rhs::Count { input: 0 }, 0, &ctx).is_ok());
    }
}
