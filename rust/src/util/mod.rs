//! Small utility substrates built in-repo because the offline crate
//! registry lacks `rand`, `proptest`, and friends (see DESIGN.md §2).

pub mod quickcheck;
pub mod rng;

/// Format a `std::time::Duration` compactly for tables (`1.23ms`, `456µs`).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns >= 1_000_000_000 {
        format!("{:.3}s", d.as_secs_f64())
    } else if ns >= 1_000_000 {
        format!("{:.3}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Left-pad a string to `w` chars (ASCII table helper).
pub fn pad(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{}{}", " ".repeat(w - s.len()), s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
        assert_eq!(fmt_duration(Duration::from_micros(12)), "12.00µs");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.000ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
    }

    #[test]
    fn pad_left() {
        assert_eq!(pad("ab", 4), "  ab");
        assert_eq!(pad("abcd", 2), "abcd");
    }
}
