//! Deterministic PRNG (xoshiro256**) — the `rand` crate is unavailable in
//! the offline registry, and determinism is a feature for the simulator:
//! every workload generator and property test is seed-reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a seed; any seed (including 0) is valid — the state is
    /// expanded with SplitMix64 so no all-zero state can occur.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)` over i64.
    pub fn gen_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as i64
    }

    /// Uniform float in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Approximate Zipf(s) sample over `[0, n)` by inverse-CDF on a
    /// precomputed table-free rejection scheme (good enough for skewed
    /// workload generation; exactness is not needed).
    pub fn gen_zipf(&mut self, n: u64, s: f64) -> u64 {
        // Rejection-inversion (Hörmann) simplified: valid for s in (0, ~3].
        debug_assert!(n >= 1);
        if s <= 0.0 {
            return self.gen_range(n);
        }
        loop {
            let u = self.gen_f64();
            // Inverse of the continuous approximation of the Zipf CDF.
            let x = if (s - 1.0).abs() < 1e-9 {
                ((n as f64 + 1.0).powf(u) - 1.0).max(0.0)
            } else {
                let t = 1.0 - s;
                ((u * ((n as f64 + 1.0).powf(t) - 1.0) + 1.0).powf(1.0 / t) - 1.0).max(0.0)
            };
            let k = x.floor() as u64;
            if k < n {
                return k;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-worker / per-day generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn range_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.gen_range(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(11);
        let mut low = 0usize;
        for _ in 0..10_000 {
            let k = r.gen_zipf(1000, 1.1);
            assert!(k < 1000);
            if k < 10 {
                low += 1;
            }
        }
        // Heavily skewed towards small ranks.
        assert!(low > 3_000, "low-rank mass {low}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
