//! Minimal property-based testing framework (in-repo `proptest` substitute
//! — the offline registry has no proptest/quickcheck; see DESIGN.md §2).
//!
//! Supports: seeded generators, configurable case counts, and greedy
//! shrinking via user-provided simplification steps. Used by the
//! coordinator-invariant tests in `rust/tests/coordination_properties.rs`
//! and by unit tests across the compiler.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this sandbox)
//! use labyrinth::util::quickcheck::{forall, Config, Gen};
//! forall(Config::default().cases(64), Gen::vec_i64(0, 100, 0..20), |xs| {
//!     let mut ys = xs.clone();
//!     ys.sort();
//!     ys.len() == xs.len()
//! });
//! ```

use crate::util::rng::Rng;
use std::fmt::Debug;

/// Property-run configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to generate.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
    /// Max shrink attempts after a failure.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0x1AB, max_shrink: 500 }
    }
}

impl Config {
    /// Set the case count.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    /// Set the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// A generator: produces a value from a PRNG and can propose shrinks.
pub struct Gen<T> {
    generate: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    /// Build from a generation function (no shrinking).
    pub fn new(f: impl Fn(&mut Rng) -> T + 'static) -> Gen<T> {
        Gen { generate: Box::new(f), shrink: Box::new(|_| Vec::new()) }
    }

    /// Attach a shrinker: returns candidate *simpler* values.
    pub fn with_shrink(mut self, f: impl Fn(&T) -> Vec<T> + 'static) -> Gen<T> {
        self.shrink = Box::new(f);
        self
    }

    /// Map the generated value (loses shrinking).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        let g = self.generate;
        Gen::new(move |r| f(g(r)))
    }

    /// Generate one value.
    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.generate)(rng)
    }
}

impl Gen<i64> {
    /// Uniform i64 in `[lo, hi)`, shrinking towards `lo`.
    pub fn i64_range(lo: i64, hi: i64) -> Gen<i64> {
        Gen::new(move |r| r.gen_i64(lo, hi)).with_shrink(move |&v| {
            let mut out = Vec::new();
            if v > lo {
                out.push(lo);
                out.push(lo + (v - lo) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        })
    }
}

impl Gen<Vec<i64>> {
    /// Vector of i64 with length in `len`, elements in `[lo, hi)`.
    /// Shrinks by halving the vector and shrinking elements towards `lo`.
    pub fn vec_i64(lo: i64, hi: i64, len: std::ops::Range<usize>) -> Gen<Vec<i64>> {
        let (lmin, lmax) = (len.start, len.end.max(len.start + 1));
        Gen::new(move |r| {
            let n = lmin + r.gen_range((lmax - lmin) as u64) as usize;
            (0..n).map(|_| r.gen_i64(lo, hi)).collect()
        })
        .with_shrink(move |v: &Vec<i64>| {
            let mut out = Vec::new();
            if v.len() > lmin {
                out.push(v[..v.len() / 2.max(lmin)].to_vec());
                let mut w = v.clone();
                w.pop();
                out.push(w);
            }
            for i in 0..v.len().min(4) {
                if v[i] > lo {
                    let mut w = v.clone();
                    w[i] = lo;
                    out.push(w);
                }
            }
            out
        })
    }
}

/// Generate a random-but-well-formed LabyLang program from a seed. The
/// family covers: loops with data-dependent trip counts, if/else over
/// loop parity and bag aggregates, loop-carried bags, invariant joins
/// (`lookup` — hoisting and build-side-selection fodder, via both `join`
/// and the build-side-flipped `joinBuild`), post-join filters on single
/// sides / keys (predicate-pushdown fodder), element-wise map/filter
/// chains (fusion fodder), keyed aggregation, scalar capture desugaring,
/// and unstructured control flow (`break`/`continue`).
///
/// Shared by the differential tests (`baseline_equivalence.rs`) and the
/// optimizer-semantics property test (`opt_semantics.rs`).
pub fn random_laby_program(seed: u64) -> String {
    let mut r = Rng::new(seed);
    let steps = 2 + r.gen_range(5); // 2..=6
    let lit: Vec<String> = (0..(3 + r.gen_range(5)))
        .map(|_| r.gen_range(50).to_string())
        .collect();
    let lit = lit.join(", ");
    let branch_kind = r.gen_range(3);
    let use_join = r.gen_bool(0.5);
    let use_carry = r.gen_bool(0.7);
    let use_chain = r.gen_bool(0.5);
    let mulk = 1 + r.gen_range(4);

    let mut body = String::new();
    body.push_str(&format!("    cur = bag({lit}).map(|v| v + i * {mulk});\n"));
    if use_chain {
        // A fusible element-wise chain, partly loop-invariant.
        body.push_str(
            "    inv = bag(3, 1, 4, 1, 5).map(|v| v + 1).filter(|v| v % 2 == 0).map(|v| v * 3);\n     cur = cur.union(inv);\n",
        );
    }
    if use_join {
        // `join` makes the invariant lookup the build side; `joinBuild`
        // makes the loop-varying receiver the build side — fodder for the
        // cost model's build-side flip.
        let join_method = if r.gen_bool(0.5) { "join" } else { "joinBuild" };
        body.push_str(&format!(
            "    kv = cur.map(|v| pair(v % 7, v));\n     j0 = kv.{join_method}(lookup);\n"
        ));
        // A filter above the join reading only the key or only one side's
        // payload — pushdown fodder (side meaning depends on the method:
        // left is `lookup` under `join`, `kv` under `joinBuild`).
        let pred = match r.gen_range(3) {
            0 => "fst(p) <= 4",
            1 => "fst(snd(p)) % 2 == 0",
            _ => "snd(snd(p)) % 3 != 1",
        };
        body.push_str(&format!(
            "    jf = j0.filter(|p| {pred});\n     j = jf.map(|p| fst(snd(p)) + snd(snd(p)));\n     collect(j, \"joined\");\n"
        ));
    }
    match branch_kind {
        0 => body.push_str(
            "    if (i % 2 == 0) { acc = acc.union(cur); } else { acc = cur; }\n",
        ),
        1 => body.push_str(
            "    n = cur.reduce(|a, b| a + b);\n    if (n % 3 == 0) { acc = cur.map(|v| v + 1); }\n",
        ),
        _ => body.push_str("    acc = acc.union(cur.filter(|v| v % 2 == 0));\n"),
    }
    // Unstructured control flow: early exits and skips.
    if r.gen_bool(0.3) {
        body.push_str("    if (i == 4) { i = i + 1; continue; }\n");
    }
    if r.gen_bool(0.3) {
        let cut = 2 + r.gen_range(3);
        body.push_str(&format!("    if (i >= {cut}) {{ break; }}\n"));
    }
    if use_carry {
        body.push_str(
            "    counts = cur.map(|v| pair(v % 5, 1)).reduceByKey(|a, b| a + b);\n     collect(counts, \"counts\");\n",
        );
    }

    format!(
        r#"
lookup = bag(0, 1, 2, 3, 4, 5, 6).map(|v| pair(v, v * 100));
acc = bag();
i = 0;
while (i < {steps}) {{
{body}    i = i + 1;
}}
collect(acc, "acc");
"#
    )
}

/// The collect labels [`random_laby_program`] may emit.
pub const RANDOM_PROGRAM_LABELS: &[&str] = &["acc", "joined", "counts"];

/// Generate a random LabyLang program whose loop carries a bag in one of
/// the two shapes `opt::delta` targets: **upsert** (`total =
/// total.union(day).reduceByKey(+)`) or **frontier** (`reach =
/// reach.union(step(reach)).distinct()`). Knobs vary literal bags, union
/// arity, element-wise steps on the frontier (including a join probing
/// an invariant lookup), and — about a quarter of the time — an in-loop
/// observer of the carried bag (`count`), which makes the loop
/// delta-INeligible and exercises the analysis' full-recompute fallback
/// rather than the rewrite. Differential suites run each program with
/// the pass forced on, forced off, and against the single-threaded
/// oracle; outputs must agree as multisets either way.
///
/// Shared by `delta_equivalence.rs` and the delta chaos leg in
/// `chaos_property.rs`.
pub fn random_delta_program(seed: u64) -> String {
    let mut r = Rng::new(seed);
    let steps = 2 + r.gen_range(5); // 2..=6
    let observe = r.gen_bool(0.25);
    // An observer consumes the carried bag inside the loop via a scalar
    // that must survive DCE — fold it into the counter increment.
    let bump = if observe { "n - n + 1" } else { "1" };
    if r.gen_bool(0.5) {
        // Upsert: per-key totals over a shifting day bag.
        let lit: Vec<String> =
            (0..(3 + r.gen_range(6))).map(|_| r.gen_range(50).to_string()).collect();
        let lit = lit.join(", ");
        let k = 3 + r.gen_range(6);
        let init = if r.gen_bool(0.5) {
            format!("bag({lit}).map(|v| pair(v % {k}, 1))")
        } else {
            "bag()".to_string()
        };
        let second_union = if r.gen_bool(0.4) {
            format!(
                "    day2 = bag({lit}).map(|v| pair((v + i) % {k}, 1));\n    merged = merged.union(day2);\n"
            )
        } else {
            String::new()
        };
        let observer = if observe { "    n = total.count();\n" } else { "" };
        format!(
            "total = {init};\ni = 0;\nwhile (i < {steps}) {{\n{observer}    day = bag({lit}).map(|v| pair((v + i * {k}) % {mod_keys}, 1));\n    merged = total.union(day);\n{second_union}    total = merged.reduceByKey(|a, b| a + b);\n    i = i + {bump};\n}}\ncollect(total, \"total\");\n",
            mod_keys = k * 3
        )
    } else {
        // Frontier: bounded closure of a functional step, optionally
        // through a filter or an invariant join probe.
        let n = 16 + r.gen_range(48); // vertex space
        let a = 1 + r.gen_range(5);
        let c = r.gen_range(7);
        let seeds: Vec<String> =
            (0..(1 + r.gen_range(3))).map(|_| r.gen_range(n).to_string()).collect();
        let seeds = seeds.join(", ");
        let step = match r.gen_range(3) {
            0 => format!("reach.map(|x| (x * {a} + {c}) % {n})"),
            1 => format!(
                "reach.map(|x| (x * {a} + {c}) % {n}).filter(|x| x % 3 != 1)"
            ),
            // `a.join(b)`: the argument is the invariant build side.
            _ => format!(
                "reach.map(|x| pair(x % 7, x)).join(lookup).map(|p| (snd(snd(p)) * {a} + fst(snd(p))) % {n})"
            ),
        };
        let observer = if observe { "    n = reach.count();\n" } else { "" };
        format!(
            "lookup = bag(0, 1, 2, 3, 4, 5, 6).map(|v| pair(v, v * 3));\nreach = bag({seeds});\ni = 0;\nwhile (i < {steps}) {{\n{observer}    next = {step};\n    reach = reach.union(next).distinct();\n    i = i + {bump};\n}}\ncollect(reach, \"reach\");\n"
        )
    }
}

/// The collect labels [`random_delta_program`] may emit.
pub const DELTA_PROGRAM_LABELS: &[&str] = &["total", "reach"];

/// Generate a random LabyLang program whose sources carry statically
/// known element types — fodder for the `opt::types` inference pass and
/// the typed columnar kernels. Returns `(program, clean)`: `clean`
/// means nothing in the program *deliberately* defeats inference — its
/// hot-chain edges (the inputs of map / filter / fused / reduceByKey /
/// join nodes) are expected to infer concrete (non-`Dyn`) types.
/// Roughly a quarter of programs are not clean: a string payload is
/// threaded through the hot path, collapsing it to `dyn` — the
/// differential suites must agree on those too, via the dynamic
/// fallback. The non-vacuousness floor in `columnar_equivalence.rs`
/// measures actual typedness from the compiled graph, so `clean` is a
/// generator-side hint, not a per-program guarantee.
///
/// Shared by `columnar_equivalence.rs` and its chaos leg.
pub fn random_typed_program(seed: u64) -> (String, bool) {
    let mut r = Rng::new(seed);
    let steps = 2 + r.gen_range(4); // 2..=5
    let lit: Vec<String> =
        (0..(4 + r.gen_range(6))).map(|_| r.gen_range(60).to_string()).collect();
    let lit = lit.join(", ");
    let a = 1 + r.gen_range(5);
    let c = r.gen_range(9);
    let k = 3 + r.gen_range(5);
    let defeat = r.gen_bool(0.25);

    // Fusible all-i64 element-wise chain — the columnar hot path.
    let chain = match r.gen_range(3) {
        0 => format!(".map(|v| v * {a} + {c}).filter(|v| v % 2 == 0)"),
        1 => format!(".map(|v| v + i).filter(|v| v % 3 != 1).map(|v| v * {a})"),
        _ => format!(".filter(|v| v >= {c}).map(|v| v - {c})"),
    };
    let mut body = format!("    cur = bag({lit}){chain};\n");
    if defeat {
        // Defeat inference ON the hot chain: a string element joins the
        // union, collapsing the carried type to dyn.
        body.push_str("    cur = cur.union(bag(\"s\").map(|v| v)).filter(|v| true);\n");
    }
    if r.gen_bool(0.6) {
        // Typed keyed aggregation: pair(i64, i64) values.
        body.push_str(&format!(
            "    counts = cur.map(|v| pair(v % {k}, 1)).reduceByKey(|a, b| a + b);\n    collect(counts, \"counts\");\n"
        ));
    }
    if r.gen_bool(0.5) {
        // Typed i64-key join probing an invariant lookup.
        body.push_str(&format!(
            "    j = cur.map(|v| pair(v % 7, v)).join(lookup).map(|p| fst(snd(p)) + snd(snd(p)));\n    collect(j, \"joined\");\n"
        ));
    }
    body.push_str("    acc = acc.union(cur);\n");
    let program = format!(
        "lookup = bag(0, 1, 2, 3, 4, 5, 6).map(|v| pair(v, v * 100));\nacc = bag();\ni = 0;\nwhile (i < {steps}) {{\n{body}    i = i + 1;\n}}\ncollect(acc, \"acc\");\n"
    );
    (program, !defeat)
}

/// The collect labels [`random_typed_program`] may emit.
pub const TYPED_PROGRAM_LABELS: &[&str] = &["acc", "counts", "joined"];

/// Channel batch sizes the property suites sweep: 1 turns every element
/// into a batch boundary (close-marker piggybacking on singleton
/// batches), 2 and 7 produce partial final flushes at odd offsets, 256
/// is the production default.
pub const BATCH_SIZES: &[usize] = &[1, 2, 7, 256];

/// Deterministic "random" batch size for a property seed — the seeded
/// families run each program at one of [`BATCH_SIZES`], so the whole
/// sweep covers every size without multiplying the suite's runtime.
pub fn batch_for_seed(seed: u64) -> usize {
    BATCH_SIZES[(seed % BATCH_SIZES.len() as u64) as usize]
}

/// Checkpoint cadences the chaos property suite sweeps
/// (`ExecConfig::checkpoint_every`): every superstep, every third, and
/// never (retry-from-scratch).
pub const CHECKPOINT_CADENCES: &[Option<u32>] = &[Some(1), Some(3), None];

/// Deterministic "random" checkpoint cadence for a property seed
/// (decorrelated from [`batch_for_seed`] so the (batch, cadence) grid
/// is covered across seeds, like the batch sweep itself).
pub fn checkpoint_for_seed(seed: u64) -> Option<u32> {
    CHECKPOINT_CADENCES[((seed / 7) % CHECKPOINT_CADENCES.len() as u64) as usize]
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult<T> {
    /// All cases passed.
    Ok,
    /// A counterexample (possibly shrunk) was found.
    Falsified {
        /// The minimal failing input found.
        input: T,
        /// Seed of the failing case, for reproduction.
        seed: u64,
        /// Number of successful shrink steps applied.
        shrinks: usize,
    },
}

/// Run `prop` on `cfg.cases` random inputs; on failure, shrink greedily.
/// Returns the outcome instead of panicking (callers assert).
pub fn check<T: Clone + Debug + 'static>(
    cfg: Config,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) -> PropResult<T> {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen.sample(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink.
        let mut best = input;
        let mut shrinks = 0;
        let mut budget = cfg.max_shrink;
        'outer: loop {
            for cand in (gen.shrink)(&best) {
                if budget == 0 {
                    break 'outer;
                }
                budget -= 1;
                if !prop(&cand) {
                    best = cand;
                    shrinks += 1;
                    continue 'outer;
                }
            }
            break;
        }
        return PropResult::Falsified { input: best, seed, shrinks };
    }
    PropResult::Ok
}

/// Like [`check`] but panics with a reproducible report on failure.
pub fn forall<T: Clone + Debug + 'static>(cfg: Config, gen: Gen<T>, prop: impl Fn(&T) -> bool) {
    match check(cfg, gen, prop) {
        PropResult::Ok => {}
        PropResult::Falsified { input, seed, shrinks } => {
            panic!("property falsified (seed={seed}, {shrinks} shrinks): {input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(Config::default().cases(50), Gen::i64_range(0, 100), |&v| v >= 0 && v < 100);
    }

    #[test]
    fn failing_property_is_found_and_shrunk() {
        let res = check(Config::default().cases(200), Gen::i64_range(0, 1000), |&v| v < 500);
        match res {
            PropResult::Falsified { input, .. } => {
                // Greedy shrinking should land near the boundary.
                assert!(input >= 500, "shrunk input {input}");
                assert!(input <= 750, "shrink did not reduce: {input}");
            }
            PropResult::Ok => panic!("property should fail"),
        }
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let g = Gen::vec_i64(5, 10, 2..6);
        let mut r = Rng::new(1);
        for _ in 0..100 {
            let v = g.sample(&mut r);
            assert!((2..6).contains(&v.len()));
            assert!(v.iter().all(|&x| (5..10).contains(&x)));
        }
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let res = check(
            Config::default().cases(100),
            Gen::vec_i64(0, 100, 0..30),
            |v: &Vec<i64>| v.len() < 10,
        );
        match res {
            PropResult::Falsified { input, .. } => assert!(input.len() >= 10 && input.len() <= 16),
            PropResult::Ok => panic!("should fail"),
        }
    }
}
