//! Dynamic element values flowing through Labyrinth dataflows.
//!
//! Labyrinth programs are written in a dynamically-typed analytics DSL
//! (LabyLang) or via the builder API; the elements of parallel `Bag`s are
//! represented uniformly by [`Value`]. `Value` is hashable and totally
//! ordered (floats compare/hash by their bit pattern under a total order),
//! so any value can be used as a partitioning or grouping key.

use std::cmp::Ordering;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// A dynamically typed value: bag elements, scalars lifted into singleton
/// bags (§5.2 of the paper), and composite pairs/tuples.
#[derive(Clone)]
pub enum Value {
    /// The unit value (used by side-effecting statements like `writeFile`).
    Unit,
    /// A boolean — condition variables evaluate to singleton `Bool` bags.
    Bool(bool),
    /// A 64-bit signed integer.
    I64(i64),
    /// A 64-bit float. Ordered/hashed by total-order bit pattern.
    F64(f64),
    /// An immutable string (cheaply cloneable).
    Str(Arc<str>),
    /// A pair; by convention the *first* component is the key of keyed
    /// operations (`join`, `reduceByKey`) and of hash partitioning.
    Pair(Arc<(Value, Value)>),
    /// An N-ary tuple for wider records.
    Tuple(Arc<Vec<Value>>),
}

impl Value {
    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(Arc::from(s.into().as_str()))
    }

    /// Build a pair value.
    pub fn pair(a: Value, b: Value) -> Value {
        Value::Pair(Arc::new((a, b)))
    }

    /// Build a tuple value.
    pub fn tuple(vs: Vec<Value>) -> Value {
        Value::Tuple(Arc::new(vs))
    }

    /// The key used by keyed operations and hash partitioning: the first
    /// component of a pair/tuple, or the value itself otherwise.
    pub fn key(&self) -> &Value {
        match self {
            Value::Pair(p) => &p.0,
            Value::Tuple(t) if !t.is_empty() => &t[0],
            other => other,
        }
    }

    /// The non-key payload of a pair (panics on other shapes).
    pub fn val(&self) -> &Value {
        match self {
            Value::Pair(p) => &p.1,
            other => panic!("Value::val on non-pair {other:?}"),
        }
    }

    /// Extract an `i64`, panicking with context otherwise.
    pub fn as_i64(&self) -> i64 {
        match self {
            Value::I64(v) => *v,
            Value::Bool(b) => *b as i64,
            other => panic!("expected I64, got {other:?}"),
        }
    }

    /// Extract an `f64` (integers widen), panicking otherwise.
    pub fn as_f64(&self) -> f64 {
        match self {
            Value::F64(v) => *v,
            Value::I64(v) => *v as f64,
            other => panic!("expected F64, got {other:?}"),
        }
    }

    /// Extract a `bool`, panicking otherwise.
    pub fn as_bool(&self) -> bool {
        match self {
            Value::Bool(b) => *b,
            other => panic!("expected Bool, got {other:?}"),
        }
    }

    /// Extract a string slice, panicking otherwise.
    pub fn as_str(&self) -> &str {
        match self {
            Value::Str(s) => s,
            other => panic!("expected Str, got {other:?}"),
        }
    }

    /// Stable 64-bit hash of the partitioning key (FxHash).
    pub fn key_hash(&self) -> u64 {
        let mut h = rustc_hash::FxHasher::default();
        self.key().hash(&mut h);
        h.finish()
    }

    /// A short type tag for error messages.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Unit => "unit",
            Value::Bool(_) => "bool",
            Value::I64(_) => "i64",
            Value::F64(_) => "f64",
            Value::Str(_) => "str",
            Value::Pair(_) => "pair",
            Value::Tuple(_) => "tuple",
        }
    }

    fn discriminant_rank(&self) -> u8 {
        match self {
            Value::Unit => 0,
            Value::Bool(_) => 1,
            Value::I64(_) => 2,
            Value::F64(_) => 3,
            Value::Str(_) => 4,
            Value::Pair(_) => 5,
            Value::Tuple(_) => 6,
        }
    }
}

/// Static element type of a dataflow edge — the lattice the `opt::types`
/// inference pass computes over (`docs/columnar.md`). `Dyn` is the top:
/// anything the analysis cannot pin down (or a join of conflicting
/// types) stays dynamic and runs on the uniform [`Value`] path. The
/// inference is *optimistic*: typed kernels re-verify element shapes per
/// batch and fall back to the dynamic path on mismatch, so a wrong type
/// here can cost performance but never correctness.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum ElemType {
    /// 64-bit signed integers.
    I64,
    /// 64-bit floats.
    F64,
    /// Booleans.
    Bool,
    /// Strings.
    Str,
    /// Pairs with statically known component types (the key/value shape
    /// of keyed operators).
    Pair(Box<ElemType>, Box<ElemType>),
    /// Tuples with statically known field types.
    Tuple(Vec<ElemType>),
    /// Unknown / mixed — the dynamic `Value` path.
    Dyn,
}

impl ElemType {
    /// Least upper bound: equal types join to themselves, pairs/tuples
    /// join componentwise, anything else collapses to [`ElemType::Dyn`].
    pub fn join(&self, other: &ElemType) -> ElemType {
        use ElemType::*;
        match (self, other) {
            (a, b) if a == b => a.clone(),
            (Pair(ak, av), Pair(bk, bv)) => {
                Pair(Box::new(ak.join(bk)), Box::new(av.join(bv)))
            }
            (Tuple(a), Tuple(b)) if a.len() == b.len() => {
                Tuple(a.iter().zip(b).map(|(x, y)| x.join(y)).collect())
            }
            _ => Dyn,
        }
    }

    /// The exact static type of one runtime value (`Unit` has no typed
    /// column representation and maps to `Dyn`).
    pub fn of_value(v: &Value) -> ElemType {
        match v {
            Value::Unit => ElemType::Dyn,
            Value::Bool(_) => ElemType::Bool,
            Value::I64(_) => ElemType::I64,
            Value::F64(_) => ElemType::F64,
            Value::Str(_) => ElemType::Str,
            Value::Pair(p) => ElemType::Pair(
                Box::new(ElemType::of_value(&p.0)),
                Box::new(ElemType::of_value(&p.1)),
            ),
            Value::Tuple(t) => {
                ElemType::Tuple(t.iter().map(ElemType::of_value).collect())
            }
        }
    }

    /// Is this type fully resolved (no `Dyn` anywhere)?
    pub fn is_typed(&self) -> bool {
        match self {
            ElemType::Dyn => false,
            ElemType::Pair(k, v) => k.is_typed() && v.is_typed(),
            ElemType::Tuple(ts) => ts.iter().all(ElemType::is_typed),
            _ => true,
        }
    }
}

impl fmt::Display for ElemType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ElemType::I64 => write!(f, "i64"),
            ElemType::F64 => write!(f, "f64"),
            ElemType::Bool => write!(f, "bool"),
            ElemType::Str => write!(f, "str"),
            ElemType::Pair(k, v) => write!(f, "pair({k},{v})"),
            ElemType::Tuple(ts) => {
                write!(f, "tuple(")?;
                for (i, t) in ts.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{t}")?;
                }
                write!(f, ")")
            }
            ElemType::Dyn => write!(f, "dyn"),
        }
    }
}

/// [`Value::key_hash`] of a bare `I64` key, without building the `Value`:
/// must produce bit-identical hashes (discriminant rank, then payload) so
/// columnar kernels can fill the scatter hash buffer from raw key columns.
pub fn i64_key_hash(k: i64) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    h.write_u8(2); // Value::I64 discriminant rank
    k.hash(&mut h);
    h.finish()
}

/// [`Value::key_hash`] of a bare `F64` key (see [`i64_key_hash`]).
pub fn f64_key_hash(k: f64) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    h.write_u8(3); // Value::F64 discriminant rank
    k.to_bits().hash(&mut h);
    h.finish()
}

impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Value {}

impl Ord for Value {
    fn cmp(&self, other: &Self) -> Ordering {
        use Value::*;
        match (self, other) {
            (Unit, Unit) => Ordering::Equal,
            (Bool(a), Bool(b)) => a.cmp(b),
            (I64(a), I64(b)) => a.cmp(b),
            // Total order over floats via IEEE-754 total-ordering trick.
            (F64(a), F64(b)) => {
                let ta = a.to_bits() as i64;
                let tb = b.to_bits() as i64;
                let ta = ta ^ (((ta >> 63) as u64) >> 1) as i64;
                let tb = tb ^ (((tb >> 63) as u64) >> 1) as i64;
                ta.cmp(&tb)
            }
            (Str(a), Str(b)) => a.cmp(b),
            (Pair(a), Pair(b)) => a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)),
            (Tuple(a), Tuple(b)) => a.cmp(b),
            (a, b) => a.discriminant_rank().cmp(&b.discriminant_rank()),
        }
    }
}
impl PartialOrd for Value {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Hash for Value {
    fn hash<H: Hasher>(&self, state: &mut H) {
        state.write_u8(self.discriminant_rank());
        match self {
            Value::Unit => {}
            Value::Bool(b) => b.hash(state),
            Value::I64(v) => v.hash(state),
            Value::F64(v) => v.to_bits().hash(state),
            Value::Str(s) => s.hash(state),
            Value::Pair(p) => {
                p.0.hash(state);
                p.1.hash(state);
            }
            Value::Tuple(t) => {
                for v in t.iter() {
                    v.hash(state);
                }
            }
        }
    }
}

impl fmt::Debug for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Unit => write!(f, "()"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::I64(v) => write!(f, "{v}"),
            Value::F64(v) => write!(f, "{v}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Pair(p) => write!(f, "({:?}, {:?})", p.0, p.1),
            Value::Tuple(t) => {
                write!(f, "(")?;
                for (i, v) in t.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v:?}")?;
                }
                write!(f, ")")
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            other => write!(f, "{other:?}"),
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::I64(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::str(v)
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::str(v)
    }
}
impl From<(Value, Value)> for Value {
    fn from((a, b): (Value, Value)) -> Self {
        Value::pair(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::hash_map::DefaultHasher;

    fn h(v: &Value) -> u64 {
        let mut s = DefaultHasher::new();
        v.hash(&mut s);
        s.finish()
    }

    #[test]
    fn key_of_pair_is_first_component() {
        let v = Value::pair(Value::I64(7), Value::str("x"));
        assert_eq!(v.key(), &Value::I64(7));
        assert_eq!(v.val(), &Value::str("x"));
    }

    #[test]
    fn key_of_scalar_is_itself() {
        let v = Value::I64(3);
        assert_eq!(v.key(), &v);
    }

    #[test]
    fn float_total_order_handles_nan_and_signed_zero() {
        let nan = Value::F64(f64::NAN);
        let one = Value::F64(1.0);
        let neg = Value::F64(-1.0);
        assert_eq!(nan.cmp(&nan), Ordering::Equal);
        assert_eq!(neg.cmp(&one), Ordering::Less);
        // NaN (positive payload) sorts above all finite values.
        assert_eq!(one.cmp(&nan), Ordering::Less);
        // -0.0 < +0.0 under total order but they hash differently; that is
        // fine for grouping as long as equality is consistent with hashing.
        let z = Value::F64(0.0);
        let nz = Value::F64(-0.0);
        assert_ne!(z, nz);
        assert_ne!(h(&z), h(&nz));
    }

    #[test]
    fn hash_consistent_with_eq() {
        let a = Value::pair(Value::I64(1), Value::str("a"));
        let b = Value::pair(Value::I64(1), Value::str("a"));
        assert_eq!(a, b);
        assert_eq!(h(&a), h(&b));
    }

    #[test]
    fn cross_type_ordering_is_by_rank() {
        assert!(Value::Bool(true) < Value::I64(0));
        assert!(Value::I64(i64::MAX) < Value::F64(f64::NEG_INFINITY));
        assert!(Value::F64(1e300) < Value::str(""));
    }

    #[test]
    fn tuple_key_is_first_field() {
        let t = Value::tuple(vec![Value::str("k"), Value::I64(1), Value::I64(2)]);
        assert_eq!(t.key(), &Value::str("k"));
    }

    #[test]
    fn display_strings_unquoted() {
        assert_eq!(Value::str("abc").to_string(), "abc");
        assert_eq!(format!("{:?}", Value::str("abc")), "\"abc\"");
    }

    #[test]
    fn key_hash_matches_between_identical_keys() {
        let a = Value::pair(Value::I64(42), Value::F64(0.5));
        let b = Value::pair(Value::I64(42), Value::str("other"));
        assert_eq!(a.key_hash(), b.key_hash());
    }

    #[test]
    fn raw_key_hashes_match_value_key_hash() {
        for k in [-3i64, 0, 1, 42, i64::MAX, i64::MIN] {
            assert_eq!(i64_key_hash(k), Value::I64(k).key_hash(), "{k}");
            assert_eq!(
                i64_key_hash(k),
                Value::pair(Value::I64(k), Value::str("p")).key_hash(),
                "pair key {k}"
            );
        }
        for f in [0.0f64, -0.0, 1.5, f64::NAN, f64::INFINITY] {
            assert_eq!(f64_key_hash(f), Value::F64(f).key_hash());
        }
    }

    #[test]
    fn elem_type_join_is_a_lattice() {
        use ElemType::*;
        assert_eq!(I64.join(&I64), I64);
        assert_eq!(I64.join(&F64), Dyn);
        assert_eq!(Dyn.join(&I64), Dyn);
        let p1 = Pair(Box::new(I64), Box::new(I64));
        let p2 = Pair(Box::new(I64), Box::new(F64));
        assert_eq!(p1.join(&p1), p1);
        assert_eq!(p1.join(&p2), Pair(Box::new(I64), Box::new(Dyn)));
        assert_eq!(p1.join(&I64), Dyn);
        assert_eq!(Tuple(vec![I64, Str]).join(&Tuple(vec![I64, Str])), Tuple(vec![I64, Str]));
        assert_eq!(Tuple(vec![I64]).join(&Tuple(vec![I64, I64])), Dyn);
    }

    #[test]
    fn elem_type_of_value_and_display() {
        let v = Value::pair(Value::I64(1), Value::F64(2.0));
        let t = ElemType::of_value(&v);
        assert_eq!(t, ElemType::Pair(Box::new(ElemType::I64), Box::new(ElemType::F64)));
        assert_eq!(t.to_string(), "pair(i64,f64)");
        assert!(t.is_typed());
        assert_eq!(ElemType::of_value(&Value::Unit), ElemType::Dyn);
        assert!(!ElemType::Pair(Box::new(ElemType::Dyn), Box::new(ElemType::I64)).is_typed());
        assert_eq!(
            ElemType::of_value(&Value::tuple(vec![Value::Bool(true), Value::str("s")]))
                .to_string(),
            "tuple(bool,str)"
        );
    }
}
