//! The control-flow coordination protocol (§6.3), as *pure* functions and
//! state machines over the execution path. The physical engine (`exec`)
//! wires these to threads and channels; keeping the logic pure makes the
//! paper's trickiest algorithms directly unit- and property-testable.
//!
//! Key concepts:
//! * **Execution path** — the walk on the CFG taken so far (sequence of
//!   basic blocks). Condition nodes extend it via the driver; every
//!   operator instance observes the same sequence (§6.3.1).
//! * **Bag identifier** — `(node, path prefix)`; transmitted as the prefix
//!   *length* only (`u32`), since all parties share the path (O(1) per
//!   block instead of O(n), §6.3.1).
//! * **Output bag choice** (§6.3.2) — a node computes one output bag per
//!   occurrence of its basic block in the path.
//! * **Input bag choice** (§6.3.3) — longest prefix of the output bag's
//!   path ending in the input's block; Φ-nodes pick the input whose
//!   prefix is longest.
//! * **Conditional output** (§6.3.4) — send a retained bag when the
//!   consumer's block appears before the producer's block recurs (for
//!   Φ-targets, before any *sibling input's* block appears).

pub mod path;

pub use path::ExecPath;

use crate::frontend::BlockId;

/// §6.3.3 — the required input bag for an output bag with path prefix
/// `out_len`: the longest prefix of `path[..out_len]` that ends with
/// `src_block`, returned as its length. `None` if the block never occurs
/// in the prefix (possible only for Φ inputs on the not-taken side).
pub fn required_input_len(path: &[BlockId], out_len: u32, src_block: BlockId) -> Option<u32> {
    debug_assert!(out_len as usize <= path.len());
    path[..out_len as usize]
        .iter()
        .rposition(|&b| b == src_block)
        .map(|i| (i + 1) as u32)
}

/// §6.3.3 Φ special case — choose among the Φ's inputs the one with the
/// longest prefix. Returns `(input index, required bag length)`.
///
/// SSA verification guarantees pairwise-distinct input blocks, so there is
/// a unique maximum among the inputs that occur at all.
///
/// `own_block`: the Φ's own basic block. An input *defined in the Φ's own
/// block* is a self-argument (`continue` creates these: the value is
/// unchanged along that path) and selects the Φ's own PREVIOUS output bag
/// — the longest **proper** prefix ending with the block.
pub fn choose_phi_input(
    path: &[BlockId],
    out_len: u32,
    input_blocks: &[BlockId],
    own_block: BlockId,
) -> Option<(usize, u32)> {
    let mut best: Option<(usize, u32)> = None;
    for (i, &b) in input_blocks.iter().enumerate() {
        let limit = if b == own_block { out_len - 1 } else { out_len };
        if let Some(len) = required_input_len(path, limit, b) {
            if best.map(|(_, bl)| len > bl).unwrap_or(true) {
                best = Some((i, len));
            }
        }
    }
    best
}

/// Decision state of a conditional-output watcher (§6.3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendDecision {
    /// Not yet determined.
    Undecided,
    /// Send the bag to the consumer (the consumer's block appeared first).
    Send,
    /// The bag will never be consumed on this edge; discard the partition.
    Dead,
}

/// Watches the execution path *after* a produced bag and decides whether
/// the bag must be sent on one conditional output edge.
///
/// * `target_block` — the consumer's block (b2);
/// * `blockers` — blocks whose appearance kills the bag: the producer's
///   own block (a newer bag supersedes this one), plus — when the consumer
///   is a Φ — the defining blocks of the Φ's *other* inputs (the Φ will
///   prefer the sibling's newer bag).
#[derive(Clone, Debug)]
pub struct OutWatcher {
    /// Path length of the bag being watched (observations start after it).
    pub bag_len: u32,
    /// Consumer block b2.
    pub target_block: BlockId,
    /// Superseding blocks.
    pub blockers: Vec<BlockId>,
    state: SendDecision,
}

impl OutWatcher {
    /// Create an undecided watcher.
    pub fn new(bag_len: u32, target_block: BlockId, blockers: Vec<BlockId>) -> OutWatcher {
        OutWatcher { bag_len, target_block, blockers, state: SendDecision::Undecided }
    }

    /// Current state.
    pub fn state(&self) -> SendDecision {
        self.state
    }

    /// Observe the path block at 1-based position `pos` (`pos > bag_len`
    /// observations only; earlier positions are ignored). Returns the
    /// (possibly updated) state.
    pub fn on_block(&mut self, pos: u32, block: BlockId) -> SendDecision {
        if self.state != SendDecision::Undecided || pos <= self.bag_len {
            return self.state;
        }
        if block == self.target_block {
            self.state = SendDecision::Send;
        } else if self.blockers.contains(&block) {
            self.state = SendDecision::Dead;
        }
        self.state
    }

    /// The path is final: anything undecided will never be consumed.
    pub fn on_final(&mut self) -> SendDecision {
        if self.state == SendDecision::Undecided {
            self.state = SendDecision::Dead;
        }
        self.state
    }
}

/// Consumer-side buffer GC (§6.3.3 "decide when to discard"): a buffered
/// input bag with id length `bag_len` on an edge is dead once
///
/// 1. a *superseding* block occurrence exists at position `j > bag_len`
///    (`supersede_blocks` = the input's own block, plus sibling input
///    blocks for Φ consumers), **and**
/// 2. every output bag that could still choose it — those with positions
///    `< j` — has already been completed (`min_pending_out`, `None` if no
///    output bag is pending).
///
/// Or unconditionally once the path is final and nothing pending remains
/// (`min_pending_out == None`).
pub fn input_bag_dead(
    bag_len: u32,
    supersede_at: Option<u32>,
    min_pending_out: Option<u32>,
    path_final: bool,
) -> bool {
    let _ = bag_len;
    match (supersede_at, min_pending_out) {
        (Some(_), None) => true,
        (Some(j), Some(p)) => p >= j,
        (None, None) => path_final,
        (None, Some(_)) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Block naming convention for tests: numbers are block ids.

    #[test]
    fn required_input_len_picks_latest_occurrence() {
        // path: E H B H B H A  (E=0, H=1, B=2, A=3)
        let path = [0, 1, 2, 1, 2, 1, 3];
        // Output at the 3rd H (len 6): input from B -> latest B is pos 5.
        assert_eq!(required_input_len(&path, 6, 2), Some(5));
        // Input from E -> pos 1.
        assert_eq!(required_input_len(&path, 6, 0), Some(1));
        // Block never occurring.
        assert_eq!(required_input_len(&path, 6, 9), None);
        // Same-block input: the prefix itself.
        assert_eq!(required_input_len(&path, 6, 1), Some(6));
    }

    #[test]
    fn phi_chooses_loop_back_after_first_step() {
        // Paper Fig. 3: Φ(day_1 from E, day_3 from B) at header H.
        let path = [0, 1, 2, 1, 2, 1, 3];
        // First H (len 2): only E has occurred.
        assert_eq!(choose_phi_input(&path, 2, &[0, 2], 1), Some((0, 1)));
        // Second H (len 4): B at pos 3 beats E at pos 1.
        assert_eq!(choose_phi_input(&path, 4, &[0, 2], 1), Some((1, 3)));
        // Third H (len 6): B at pos 5.
        assert_eq!(choose_phi_input(&path, 6, &[0, 2], 1), Some((1, 5)));
    }

    #[test]
    fn phi_listing3b_interleaving() {
        // Listing 3b: while { if then B(x1,y1) else C(x2,y2); D: Φ }.
        // Blocks: A=0 (header+cond), B=1, C=2, D=3. Path ABDACD.
        let path = [0, 1, 3, 0, 2, 3];
        // First D (len 3): x-Φ inputs from B and C -> B (pos 2).
        assert_eq!(choose_phi_input(&path, 3, &[1, 2], 3), Some((0, 2)));
        // Second D (len 6): C at pos 5 wins.
        assert_eq!(choose_phi_input(&path, 6, &[1, 2], 3), Some((1, 5)));
    }

    #[test]
    fn phi_self_argument_selects_previous_own_bag() {
        // `continue` pattern: Φ at header H(1) with args from E(0), latch
        // M(2), and ITSELF (continue path carries the value unchanged).
        // Path: E H B M H B T H   (B=3 body, T=4 continue-then block)
        let path = [0, 1, 3, 2, 1, 3, 4, 1];
        // 2nd H (len 5): latch M at pos 4 wins over self (prev H at 2).
        assert_eq!(choose_phi_input(&path, 5, &[0, 2, 1], 1), Some((1, 4)));
        // 3rd H (len 8): continue taken — no M since pos 4; self-arg picks
        // the Φ's own bag from the 2nd H (pos 5), NOT the current one.
        assert_eq!(choose_phi_input(&path, 8, &[0, 2, 1], 1), Some((2, 5)));
        // 1st H (len 2): only the initial value exists.
        assert_eq!(choose_phi_input(&path, 2, &[0, 2, 1], 1), Some((0, 1)));
    }

    #[test]
    fn watcher_sends_when_target_first() {
        // Producer in body B(2), consumer Φ in header H(1).
        // Bag produced at B (len 3 of path E H B); H appended at pos 4.
        let mut w = OutWatcher::new(3, 1, vec![2]);
        assert_eq!(w.on_block(4, 1), SendDecision::Send);
    }

    #[test]
    fn watcher_dies_when_producer_recurs_first() {
        // Same edge; suppose (hypothetically) B recurs before H.
        let mut w = OutWatcher::new(3, 1, vec![2]);
        assert_eq!(w.on_block(4, 2), SendDecision::Dead);
    }

    #[test]
    fn watcher_ignores_stale_positions_and_stays_decided() {
        let mut w = OutWatcher::new(3, 1, vec![2]);
        assert_eq!(w.on_block(2, 1), SendDecision::Undecided); // pos <= bag_len
        assert_eq!(w.on_block(4, 5), SendDecision::Undecided); // unrelated block
        assert_eq!(w.on_block(5, 1), SendDecision::Send);
        assert_eq!(w.on_block(6, 2), SendDecision::Send); // latched
    }

    #[test]
    fn watcher_phi_sibling_blocks_kill() {
        // Listing 3b: x1 produced in B (len 2 of path A B); Φ in D(3);
        // sibling x2 defined in C(2). Path continues A C D:
        let mut w = OutWatcher::new(2, 3, vec![1, 2]);
        assert_eq!(w.on_block(3, 0), SendDecision::Undecided); // A
        assert_eq!(w.on_block(4, 2), SendDecision::Dead); // C kills it
    }

    #[test]
    fn watcher_final_kills_undecided() {
        let mut w = OutWatcher::new(2, 3, vec![1]);
        assert_eq!(w.on_final(), SendDecision::Dead);
        // Already-sent watchers stay sent.
        let mut w2 = OutWatcher::new(2, 3, vec![1]);
        w2.on_block(3, 3);
        assert_eq!(w2.on_final(), SendDecision::Send);
    }

    #[test]
    fn input_gc_rules() {
        // Superseded at 5, everything before completed: dead.
        assert!(input_bag_dead(2, Some(5), None, false));
        assert!(input_bag_dead(2, Some(5), Some(6), false));
        // An output at position 4 may still use the bag: alive.
        assert!(!input_bag_dead(2, Some(5), Some(4), false));
        // Not superseded: alive until the path is final and drained.
        assert!(!input_bag_dead(2, None, Some(4), false));
        assert!(!input_bag_dead(2, None, None, false));
        assert!(input_bag_dead(2, None, None, true));
    }
}
