//! The execution path (§6.3.1): the worker-local replica of the global
//! walk on the CFG, maintained from condition-node broadcasts, with
//! per-block occurrence indexes for O(log k) supersession queries.

use crate::frontend::BlockId;

/// Worker-local execution path replica.
#[derive(Clone, Debug, Default)]
pub struct ExecPath {
    blocks: Vec<BlockId>,
    /// occurrences[b] = sorted 1-based positions where block b occurs.
    occurrences: Vec<Vec<u32>>,
    finalized: bool,
}

impl ExecPath {
    /// Empty path over a CFG with `num_blocks` blocks.
    pub fn new(num_blocks: usize) -> ExecPath {
        ExecPath { blocks: Vec::new(), occurrences: vec![Vec::new(); num_blocks], finalized: false }
    }

    /// Append broadcast blocks starting at 0-based position `start`
    /// (idempotent across duplicate delivery; positions must line up).
    pub fn append(&mut self, start: usize, blocks: &[BlockId], final_: bool) {
        assert!(
            start <= self.blocks.len(),
            "append gap: path len {} but broadcast starts at {start}",
            self.blocks.len()
        );
        for (k, &b) in blocks.iter().enumerate() {
            let pos = start + k;
            if pos < self.blocks.len() {
                assert_eq!(self.blocks[pos], b, "conflicting path broadcast at {pos}");
                continue;
            }
            self.blocks.push(b);
            self.occurrences[b].push((pos + 1) as u32);
        }
        if final_ {
            self.finalized = true;
        }
    }

    /// Current length.
    pub fn len(&self) -> u32 {
        self.blocks.len() as u32
    }

    /// True when no blocks have been appended yet.
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Whether the walk is complete (terminal block appended).
    pub fn is_final(&self) -> bool {
        self.finalized
    }

    /// The blocks as a slice.
    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Block at 1-based position.
    pub fn at(&self, pos: u32) -> BlockId {
        self.blocks[(pos - 1) as usize]
    }

    /// 1-based positions of a block's occurrences.
    pub fn occurrences(&self, block: BlockId) -> &[u32] {
        &self.occurrences[block]
    }

    /// First occurrence of `block` strictly after position `after`
    /// (1-based), if any.
    pub fn next_occurrence_after(&self, block: BlockId, after: u32) -> Option<u32> {
        let occ = &self.occurrences[block];
        match occ.binary_search(&(after + 1)) {
            Ok(i) => Some(occ[i]),
            Err(i) => occ.get(i).copied(),
        }
    }

    /// Earliest occurrence strictly after `after` among several blocks.
    pub fn next_occurrence_of_any(&self, blocks: &[BlockId], after: u32) -> Option<u32> {
        blocks
            .iter()
            .filter_map(|&b| self.next_occurrence_after(b, after))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_tracks_occurrences() {
        let mut p = ExecPath::new(4);
        p.append(0, &[0, 1], false);
        p.append(2, &[2, 1], false);
        assert_eq!(p.len(), 4);
        assert_eq!(p.occurrences(1), &[2, 4]);
        assert_eq!(p.at(3), 2);
        assert!(!p.is_final());
        p.append(4, &[3], true);
        assert!(p.is_final());
    }

    #[test]
    fn duplicate_delivery_is_idempotent() {
        let mut p = ExecPath::new(3);
        p.append(0, &[0, 1], false);
        p.append(0, &[0, 1, 2], false);
        assert_eq!(p.len(), 3);
        assert_eq!(p.occurrences(0), &[1]);
    }

    #[test]
    #[should_panic(expected = "append gap")]
    fn gap_panics() {
        let mut p = ExecPath::new(3);
        p.append(1, &[1], false);
    }

    #[test]
    fn next_occurrence_queries() {
        let mut p = ExecPath::new(4);
        p.append(0, &[0, 1, 2, 1, 2, 1, 3], false);
        assert_eq!(p.next_occurrence_after(1, 2), Some(4));
        assert_eq!(p.next_occurrence_after(1, 6), None);
        assert_eq!(p.next_occurrence_after(3, 0), Some(7));
        assert_eq!(p.next_occurrence_of_any(&[2, 3], 5), Some(7));
        assert_eq!(p.next_occurrence_of_any(&[0], 1), None);
    }
}
