//! Differential testing for the delta-incremental iteration engine
//! (`opt::delta`): a seeded family of loop-carried-bag programs runs
//! with the pass forced ON, forced OFF, and against the single-threaded
//! oracle — outputs must agree as multisets at every channel batch size.
//! A chaos leg injects mid-loop worker panics with delta on and checks
//! that recovery restores solution sets from `EpochCheckpoint` snapshots
//! (outputs identical, recovery bookkeeping exact).

use labyrinth::baselines::single_thread;
use labyrinth::exec::{run, ExecConfig, FaultPlan};
use labyrinth::frontend::parse_and_lower;
use labyrinth::opt::{DeltaGate, OptConfig};
use labyrinth::util::quickcheck::{
    checkpoint_for_seed, random_delta_program, BATCH_SIZES, DELTA_PROGRAM_LABELS,
};
use labyrinth::value::Value;
use std::sync::Arc;
use std::time::Duration;

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

fn gate_cfg(gate: DeltaGate) -> OptConfig {
    OptConfig { delta: gate, ..Default::default() }
}

#[test]
fn random_delta_programs_agree_on_off_and_with_oracle() {
    let mut rewritten = 0usize;
    for seed in 0..24u64 {
        let src = random_delta_program(seed);
        let program = parse_and_lower(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: parse/lower failed: {e}\n{src}"));
        let oracle = single_thread::run(&program, &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: oracle failed: {e}\n{src}"));
        let (g_on, rep) = labyrinth::compile_with(&program, &gate_cfg(DeltaGate::Always))
            .unwrap_or_else(|e| panic!("seed {seed}: delta-on compile failed: {e}\n{src}"));
        let (g_off, rep_off) = labyrinth::compile_with(&program, &gate_cfg(DeltaGate::Never))
            .unwrap_or_else(|e| panic!("seed {seed}: delta-off compile failed: {e}\n{src}"));
        assert_eq!(rep_off.delta_loops, 0, "seed {seed}: Never gate rewrote a loop\n{src}");
        rewritten += usize::from(rep.delta_loops > 0);

        for &batch in BATCH_SIZES {
            for (graph, mode) in [(&g_on, "delta-on"), (&g_off, "delta-off")] {
                let out = run(
                    graph,
                    &ExecConfig { workers: 2, batch, ..Default::default() },
                )
                .unwrap_or_else(|e| panic!("seed {seed} {mode} batch={batch}: {e}\n{src}"));
                for label in DELTA_PROGRAM_LABELS {
                    assert_eq!(
                        multiset(out.collected(label).to_vec()),
                        multiset(oracle.collected(label).to_vec()),
                        "seed {seed} label {label} {mode} batch={batch} (delta_loops={})\n{src}",
                        rep.delta_loops,
                    );
                }
            }
        }
    }
    // The sweep must actually exercise the rewrite, not pass vacuously
    // on universal fallback (the generator makes ~1/4 of loops
    // ineligible on purpose).
    assert!(rewritten >= 8, "only {rewritten}/24 seeds were delta-rewritten");
}

#[test]
fn delta_loops_survive_midloop_panics() {
    for seed in 0..12u64 {
        let src = random_delta_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let (graph, rep) =
            labyrinth::compile_with(&program, &gate_cfg(DeltaGate::Always)).unwrap();
        for &checkpoint_every in &[Some(1u32), Some(3), None] {
            // Panic worker 1 mid-loop (superstep 2): with a checkpoint
            // cadence the resume restores Φ solution sets and reducer
            // partials from the epoch snapshot; without one, the epoch
            // retries from scratch and the state rebuilds.
            let cfg = ExecConfig {
                workers: 2,
                checkpoint_every,
                faults: Some(Arc::new(FaultPlan::new().panic_at(1, 2))),
                stall_timeout: Duration::from_secs(30),
                ..Default::default()
            };
            let out = run(&graph, &cfg).unwrap_or_else(|e| {
                panic!("seed {seed} ckpt={checkpoint_every:?}: {e}\n{src}")
            });
            for label in DELTA_PROGRAM_LABELS {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(oracle.collected(label).to_vec()),
                    "seed {seed} label {label} ckpt={checkpoint_every:?} (delta_loops={})\n{src}",
                    rep.delta_loops,
                );
            }
            assert_eq!(out.metrics.get("exec.faults_injected"), 1, "seed {seed}");
            assert_eq!(out.metrics.get("exec.epoch_retries"), 1, "seed {seed}");
            let recovered = out.metrics.get("exec.supersteps_recovered");
            if recovered > 0 {
                assert_eq!(
                    recovered + out.metrics.get("exec.supersteps_replayed"),
                    out.path_len as u64,
                    "seed {seed}: recovered + replayed must cover the path\n{src}"
                );
            }
        }
    }
}

#[test]
fn delta_loops_survive_seeded_fault_schedules() {
    for seed in 20..36u64 {
        let src = random_delta_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let (graph, _) =
            labyrinth::compile_with(&program, &gate_cfg(DeltaGate::Always)).unwrap();
        let cfg = ExecConfig {
            workers: 2,
            checkpoint_every: checkpoint_for_seed(seed),
            faults: Some(Arc::new(FaultPlan::seeded(seed))),
            stall_timeout: Duration::from_secs(30),
            ..Default::default()
        };
        let out = run(&graph, &cfg)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{src}"));
        for label in DELTA_PROGRAM_LABELS {
            assert_eq!(
                multiset(out.collected(label).to_vec()),
                multiset(oracle.collected(label).to_vec()),
                "seed {seed} label {label}\n{src}"
            );
        }
    }
}
