//! Optimizer-semantics property test: for a seeded family of random
//! imperative programs (shared with `baseline_equivalence.rs` via
//! `util::quickcheck`), the optimized dataflow's execution output equals
//! the unoptimized graph's output and the single-threaded specification
//! executor's output — every pass, alone and composed, preserves program
//! semantics.

use labyrinth::baselines::single_thread;
use labyrinth::exec::{run, ExecConfig, ExecMode};
use labyrinth::frontend::parse_and_lower;
use labyrinth::opt::OptConfig;
use labyrinth::util::quickcheck::{
    batch_for_seed, random_laby_program, BATCH_SIZES, RANDOM_PROGRAM_LABELS,
};
use labyrinth::value::Value;

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

fn check_config(seed: u64, src: &str, ocfg: &OptConfig, what: &str) {
    // The channel batch size is randomized per seed over {1, 2, 7, 256}
    // so batch-boundary bugs (close-marker piggybacking on singleton
    // batches, partial final flushes) surface across the family.
    let batch = batch_for_seed(seed);
    let program = parse_and_lower(src)
        .unwrap_or_else(|e| panic!("seed {seed}: parse/lower failed: {e}\n{src}"));
    let oracle = single_thread::run(&program, &Default::default())
        .unwrap_or_else(|e| panic!("seed {seed}: oracle failed: {e}\n{src}"));
    let (graph, report) = labyrinth::compile_with(&program, ocfg)
        .unwrap_or_else(|e| panic!("seed {seed} [{what}]: compile failed: {e}\n{src}"));
    for workers in [1usize, 3] {
        for mode in [ExecMode::Pipelined, ExecMode::Barrier] {
            let out = run(&graph, &ExecConfig { workers, mode, batch, ..Default::default() })
                .unwrap_or_else(|e| {
                    panic!(
                        "seed {seed} [{what}] w={workers} {mode:?} batch={batch}: {e}\n{src}\n{}",
                        report.render()
                    )
                });
            for label in RANDOM_PROGRAM_LABELS {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(oracle.collected(label).to_vec()),
                    "seed {seed} [{what}] label {label} workers {workers} {mode:?} batch={batch}\n{src}\n{}",
                    report.render()
                );
            }
        }
    }
}

#[test]
fn optimized_graphs_match_the_specification_executor() {
    for seed in 0..16u64 {
        let src = random_laby_program(seed);
        check_config(seed, &src, &OptConfig::default(), "all");
    }
}

#[test]
fn each_pass_alone_preserves_semantics() {
    let none = OptConfig::none();
    let configs = [
        ("hoist", OptConfig { hoist: true, ..none }),
        ("fuse", OptConfig { fuse: true, ..none }),
        ("dce", OptConfig { dce: true, ..none }),
        ("pushdown", OptConfig { pushdown: true, ..none }),
        ("joinside", OptConfig { join_sides: true, ..none }),
        // Pushdown + joinside interact (a pushed filter changes the side
        // estimates) — cover the pair as well as the full default stack.
        ("pushdown+joinside", OptConfig { pushdown: true, join_sides: true, ..none }),
    ];
    for seed in 100..110u64 {
        let src = random_laby_program(seed);
        for (what, ocfg) in &configs {
            check_config(seed, &src, ocfg, what);
        }
    }
}

#[test]
fn optimizer_actually_fires_on_the_family() {
    // The property above would pass vacuously if the passes never
    // triggered; make sure the program family exercises them.
    let (mut hoisted, mut fused, mut pushed) = (0usize, 0usize, 0usize);
    for seed in 0..16u64 {
        let program = parse_and_lower(&random_laby_program(seed)).unwrap();
        let (_, report) = labyrinth::compile_with(&program, &OptConfig::default()).unwrap();
        hoisted += report.hoisted;
        fused += report.fused_chains;
        pushed += report.pushed_filters;
    }
    assert!(hoisted > 0, "no seed produced a hoistable node");
    assert!(fused > 0, "no seed produced a fusible chain");
    assert!(pushed > 0, "no seed produced a pushable post-join filter");
    // Build-side flips need a clear size skew; use a deterministic
    // program (the random family's sides are too close to call).
    labyrinth::workload::registry::global()
        .put("opt_sem_big", (0..256).map(Value::I64).collect());
    labyrinth::workload::registry::global()
        .put("opt_sem_small", (0..8).map(Value::I64).collect());
    let program = parse_and_lower(
        "big = source(\"opt_sem_big\").map(|v| pair(v % 8, v)); small = source(\"opt_sem_small\").map(|v| pair(v % 8, v)); j = big.joinBuild(small); collect(j, \"j\");",
    )
    .unwrap();
    let (_, report) = labyrinth::compile_with(&program, &OptConfig::default()).unwrap();
    assert!(report.join_flips > 0, "skewed joinBuild must flip:\n{}", report.render());
    labyrinth::workload::registry::global().clear_prefix("opt_sem_");
}

#[test]
fn zero_trip_loop_over_unregistered_source_runs_under_default_config() {
    // Regression for the always-on speculation contract: hoisting the
    // NamedSource out of a loop that provably never runs used to execute
    // it at loop entry and panic on the unregistered name. The cost gate
    // (trips = Exact(0) → below threshold) must keep it lazy, and the run
    // must complete cleanly under the DEFAULT optimizer configuration.
    let src = r#"
        d = 9;
        while (d < 3) {
            v = source("opt_sem_never_registered").map(|x| pair(x, x));
            collect(v, "v");
            d = d + 1;
        }
        collect(bag(1, 2), "ok");
    "#;
    let program = parse_and_lower(src).unwrap();
    let (graph, report) =
        labyrinth::compile_with(&program, &OptConfig::default()).unwrap();
    assert!(
        graph.nodes.iter().all(|n| !(matches!(
            n.op,
            labyrinth::frontend::Rhs::NamedSource(_)
        ) && n.hoisted_from.is_some())),
        "zero-trip source must stay in the loop:\n{}",
        report.render()
    );
    let out = run(&graph, &ExecConfig { workers: 2, ..Default::default() })
        .expect("zero-trip loop over an unregistered source must not fail");
    assert!(out.collected("v").is_empty());
    assert_eq!(
        multiset(out.collected("ok").to_vec()),
        vec![Value::I64(1), Value::I64(2)]
    );
}

#[test]
fn every_batch_size_agrees_on_the_same_program() {
    // The same optimized graph run at batch ∈ {1, 2, 7, 256} AND through
    // the legacy element-at-a-time data plane must produce identical
    // multisets — batched and element-wise execution agree exactly.
    for seed in [0u64, 5, 11] {
        let src = random_laby_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let (graph, _) = labyrinth::compile_with(&program, &OptConfig::default()).unwrap();
        let reference = run(
            &graph,
            &ExecConfig { workers: 2, element_path: true, ..Default::default() },
        )
        .unwrap_or_else(|e| panic!("seed {seed} element path: {e}\n{src}"));
        for &batch in BATCH_SIZES {
            // element_path pinned false: the batched side must stay
            // batched even when LABY_ELEMENT_PATH=1 is set process-wide
            // (the CI element-path leg), or this agreement test would
            // compare the element path against itself.
            let out = run(
                &graph,
                &ExecConfig { workers: 2, batch, element_path: false, ..Default::default() },
            )
            .unwrap_or_else(|e| panic!("seed {seed} batch={batch}: {e}\n{src}"));
            for label in RANDOM_PROGRAM_LABELS {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(reference.collected(label).to_vec()),
                    "seed {seed} label {label} batch={batch}\n{src}"
                );
            }
        }
    }
}

#[test]
fn optimizer_toggle_never_changes_results() {
    for seed in 200..208u64 {
        let src = random_laby_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let (on, _) = labyrinth::compile_with(&program, &OptConfig::default()).unwrap();
        let (off, _) = labyrinth::compile_with(&program, &OptConfig::none()).unwrap();
        let a = run(&on, &ExecConfig { workers: 2, ..Default::default() }).unwrap();
        let b = run(&off, &ExecConfig { workers: 2, ..Default::default() }).unwrap();
        for label in RANDOM_PROGRAM_LABELS {
            assert_eq!(
                multiset(a.collected(label).to_vec()),
                multiset(b.collected(label).to_vec()),
                "seed {seed} label {label}\n{src}"
            );
        }
    }
}

#[test]
fn cross_loop_fusion_differential_on_scalar_heavy_program() {
    // Differential case for the xfuse pass: a deterministic program
    // whose control path is all lifted scalar chains — a compound loop
    // condition, a nested loop, and straight-line scalar code split by
    // the loops — executed with and without the optimizer against the
    // specification executor. The default pipeline must actually fold
    // the chains (cross_loop_fusions > 0) and change nothing observable.
    let src = r#"
        d = 1;
        acc = 0;
        while (d * 2 <= 14) {
            w = 0;
            while (w < 2) {
                acc = acc + d;
                w = w + 1;
            }
            d = d + 1;
        }
        e = d + 100;
        f = e * 2;
        out = bag(1, 2, 3).map(|x| x * f + acc);
        collect(out, "out");
    "#;
    let program = parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let (on, report) = labyrinth::compile_with(&program, &OptConfig::default()).unwrap();
    assert!(
        report.cross_loop_fusions > 0,
        "premise: the scalar chains must trigger xfuse\n{}",
        report.render()
    );
    let (off, _) = labyrinth::compile_with(&program, &OptConfig::none()).unwrap();
    for workers in [1usize, 3] {
        for mode in [ExecMode::Pipelined, ExecMode::Barrier] {
            for graph in [&on, &off] {
                let out =
                    run(graph, &ExecConfig { workers, mode, ..Default::default() }).unwrap();
                assert_eq!(
                    multiset(out.collected("out").to_vec()),
                    multiset(oracle.collected("out").to_vec()),
                    "workers {workers} {mode:?}\n{}",
                    report.render()
                );
            }
        }
    }
}
