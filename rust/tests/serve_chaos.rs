//! Serve-tier chaos: deterministic fault plans injected through
//! `JobRequest::faults` against a live `JobService`. Faulted jobs must
//! recover (counted under `serve.epochs_recovered`, never
//! `serve.jobs_failed`), the resident pools must survive a concurrent
//! fault storm mixed with cancellations, cross-job preamble sharing
//! must keep hitting after a faulted run, recovered epochs must not
//! leak one tenant's state into another's, and the job deadline must
//! bound ALL retry attempts together.

use labyrinth::exec::{ExecConfig, FaultPlan};
use labyrinth::serve::{JobRequest, JobService, ServeConfig};
use labyrinth::value::Value;
use std::sync::Arc;
use std::time::Duration;

/// Loop program: several supersteps, so a panic at superstep 2 lands
/// mid-epoch and (with `checkpoint_every: 1`) resumes from a cut.
const LOOP_SRC: &str = "v = source(\"chaos_data\"); d = 1; s = bag(); while (d <= 3) { s = v.map(|x| x + d); d = d + 1; } collect(s, \"out\");";

fn dataset(seed: i64, len: i64) -> Vec<Value> {
    (0..len).map(|i| Value::I64(seed + i)).collect()
}

/// One-shot oracle on an isolated registry (never the global one).
fn one_shot(src: &str, binds: &[(&str, Vec<Value>)], workers: usize) -> Vec<Value> {
    let reg = Arc::new(labyrinth::workload::registry::Registry::new());
    for (name, data) in binds {
        reg.put(name, data.clone());
    }
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let (graph, _) = labyrinth::compile_with_registry(
        &program,
        &labyrinth::opt::OptConfig::default(),
        &reg,
    )
    .unwrap();
    let out = labyrinth::exec::run(
        &graph,
        &ExecConfig { workers, registry: reg, ..Default::default() },
    )
    .unwrap();
    let mut got = out.collected("out").to_vec();
    got.sort();
    got
}

#[test]
fn faulted_job_recovers_and_is_not_counted_failed() {
    // Regression for the recovery/accounting split: a job whose epoch
    // panics mid-run completes via retry, lands in `jobs_completed` +
    // `epochs_recovered`, and `jobs_failed` stays untouched.
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        checkpoint_every: Some(1),
        adaptive: false,
        ..Default::default()
    });
    let want = one_shot(LOOP_SRC, &[("chaos_data", dataset(5, 12))], 2);
    let res = svc
        .run(
            JobRequest::source(LOOP_SRC)
                .bind("chaos_data", dataset(5, 12))
                .faults(FaultPlan::new().panic_at(0, 2)),
        )
        .expect("faulted job must recover, not fail");
    let mut got = res.output.collected("out").to_vec();
    got.sort();
    assert_eq!(got, want);
    // The fault really fired and was retried inside the service.
    assert_eq!(res.output.metrics.get("exec.faults_injected"), 1);
    assert_eq!(res.output.metrics.get("exec.epoch_retries"), 1);
    let m = svc.metrics();
    assert_eq!(m.get("serve.jobs_completed"), 1);
    assert_eq!(m.get("serve.jobs_failed"), 0, "recovered epoch counted as a failure");
    assert_eq!(m.get("serve.epochs_recovered"), 1);
}

#[test]
fn fault_storm_over_concurrent_burst_keeps_pool_live() {
    // Mixed burst: faulted jobs (explicit panic plans, distinct victims
    // and supersteps), clean jobs, and one canceled long-runner — all
    // racing over two lanes. Everything not canceled completes with
    // exact output, and the lanes serve a fresh job afterwards.
    const FAULTED: usize = 4;
    const CLEAN: usize = 4;
    let svc = Arc::new(JobService::new(ServeConfig {
        slots: 2,
        workers: 2,
        checkpoint_every: Some(1),
        adaptive: false,
        ..Default::default()
    }));
    let expected: Vec<Vec<Value>> = (0..FAULTED + CLEAN)
        .map(|i| one_shot(LOOP_SRC, &[("chaos_data", dataset(i as i64 * 10, 12))], 2))
        .collect();

    // Cancellation victim: long enough that the cancel always lands
    // before completion, queued or running.
    let canceled = svc
        .submit(JobRequest::source(
            "d = 1; while (d <= 20000000) { d = d + 1; } collect(bag(1), \"x\");",
        ))
        .unwrap();

    std::thread::scope(|s| {
        for i in 0..FAULTED + CLEAN {
            let svc = svc.clone();
            let expected = &expected;
            s.spawn(move || {
                let mut req = JobRequest::source(LOOP_SRC)
                    .bind("chaos_data", dataset(i as i64 * 10, 12));
                if i < FAULTED {
                    // Vary victim and superstep across the storm.
                    req = req.faults(
                        FaultPlan::new().panic_at(i % 2, 1 + (i % 3) as u32),
                    );
                }
                let res = svc.run(req).unwrap_or_else(|e| panic!("job {i}: {e}"));
                let mut got = res.output.collected("out").to_vec();
                got.sort();
                assert_eq!(got, expected[i], "job {i} diverged");
            });
        }
        canceled.cancel();
    });
    let err = canceled.wait().unwrap_err();
    assert!(err.to_string().contains("canceled"), "{err}");

    let m = svc.metrics();
    assert_eq!(m.get("serve.jobs_completed"), (FAULTED + CLEAN) as u64);
    assert_eq!(m.get("serve.jobs_canceled"), 1);
    assert_eq!(m.get("serve.jobs_failed"), 0, "a recovered or canceled job leaked into jobs_failed");
    // Every faulted job recovered at least once (clean jobs may add more
    // under a process-wide LABY_FAULTS chaos leg).
    assert!(
        m.get("serve.epochs_recovered") >= FAULTED as u64,
        "expected >= {FAULTED} recoveries, got {}",
        m.get("serve.epochs_recovered")
    );
    // The storm left both lanes (and their resident pools) serviceable.
    let ok = svc.run(JobRequest::source("collect(bag(9), \"alive\");")).unwrap();
    assert_eq!(ok.output.collected("alive"), &[Value::I64(9)]);
}

/// Loop with an invariant (hoistable, binding-determined) lookup chain —
/// the cross-job preamble-sharing shape from `serve_service.rs`.
const PREAMBLE_SRC: &str = r#"
    d = 1;
    while (d <= 3) {
        attrs = source("pre_attrs").map(|x| pair(x % 8, x));
        v = source("pre_probe").map(|x| pair(x % 8, d));
        j = v.join(attrs);
        t = j.map(|p| snd(snd(p)));
        collect(t, "out");
        d = d + 1;
    }
"#;

#[test]
fn preamble_sharing_still_hits_after_faulted_runs() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        checkpoint_every: Some(1),
        adaptive: false,
        ..Default::default()
    });
    let attrs: Vec<Value> = (0..8).map(Value::I64).collect();
    let probe: Vec<Value> = (0..16).map(Value::I64).collect();
    let want = one_shot(
        PREAMBLE_SRC,
        &[("pre_attrs", attrs.clone()), ("pre_probe", probe.clone())],
        2,
    );
    let run_with = |faults: Option<FaultPlan>| -> Vec<Value> {
        let mut req = JobRequest::source(PREAMBLE_SRC)
            .bind("pre_attrs", attrs.clone())
            .bind("pre_probe", probe.clone());
        if let Some(plan) = faults {
            req = req.faults(plan);
        }
        let res = svc.run(req).unwrap();
        let mut got = res.output.collected("out").to_vec();
        got.sort();
        got
    };

    // Miss materializes the preamble bags.
    assert_eq!(run_with(None), want);
    assert_eq!(svc.metrics().get("serve.preamble_hits"), 0);
    // A faulted identical submission replays them, crashes mid-epoch,
    // recovers — and must still produce the exact result.
    assert_eq!(run_with(Some(FaultPlan::new().panic_at(1, 2))), want);
    assert_eq!(
        svc.metrics().get("serve.preamble_hits"),
        1,
        "faulted run must still resolve through the shared preamble"
    );
    assert!(svc.metrics().get("serve.epochs_recovered") >= 1);
    // The store survived the crashed epoch: later identical submissions
    // keep replaying.
    assert_eq!(run_with(None), want);
    assert_eq!(svc.metrics().get("serve.preamble_hits"), 2);
}

#[test]
fn recovered_epochs_do_not_bleed_state_across_tenants() {
    // §7 reuse keeps a loop-invariant hash-join build side across steps
    // WITHIN a job. Tenant A's epoch crashes and recovers (restoring
    // instance state from A's checkpoint); tenant B then submits the
    // same cached template with different build data. Any checkpoint
    // residue surviving into B's epoch would join against A's table.
    let src = r#"
        attrs = source("tenant_attrs");
        d = 1;
        while (d <= 3) {
            v = source("tenant_probe").map(|x| pair(x, d));
            j = attrs.join(v);
            t = j.map(|p| fst(snd(p)));
            collect(t, "out");
            d = d + 1;
        }
    "#;
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        reuse_state: true,
        checkpoint_every: Some(1),
        adaptive: false,
        ..Default::default()
    });
    let attrs_a: Vec<Value> = (0..8).map(|k| Value::pair(Value::I64(k), Value::I64(k))).collect();
    let attrs_b: Vec<Value> =
        (0..8).map(|k| Value::pair(Value::I64(k), Value::I64(k + 1000))).collect();
    let probe: Vec<Value> = (0..8).map(Value::I64).collect();
    let run_with = |attrs: &[Value], faults: Option<FaultPlan>| -> i64 {
        let mut req = JobRequest::source(src)
            .bind("tenant_attrs", attrs.to_vec())
            .bind("tenant_probe", probe.clone());
        if let Some(plan) = faults {
            req = req.faults(plan);
        }
        let res = svc.run(req).unwrap();
        res.output.collected("out").iter().map(|v| v.as_i64()).sum()
    };
    // Tenant A crashes at superstep 2 and recovers from A's checkpoint.
    let sum_a = run_with(&attrs_a, Some(FaultPlan::new().panic_at(0, 2)));
    assert_eq!(sum_a, 3 * (0..8).sum::<i64>(), "tenant A's recovered run is wrong");
    assert!(svc.metrics().get("serve.epochs_recovered") >= 1);
    // Tenant B (clean) must see ONLY B's build side.
    let sum_b = run_with(&attrs_b, None);
    assert_eq!(
        sum_b,
        3 * (1000..1008).sum::<i64>(),
        "tenant B saw tenant A's checkpointed build table"
    );
    // And a faulted B run restores B's checkpoint, not A's.
    let sum_b2 = run_with(&attrs_b, Some(FaultPlan::new().panic_at(1, 3)));
    assert_eq!(sum_b2, 3 * (1000..1008).sum::<i64>(), "recovered tenant B joined A's table");
}

#[test]
fn deadline_spans_all_retry_attempts() {
    // The straggler burns most of the budget, then the panic makes the
    // attempt retryable — but the ORIGINAL deadline has passed, so the
    // service must answer DeadlineExceeded instead of quietly rerunning
    // the epoch on a fresh clock. (Depending on scheduling the driver's
    // own deadline poll may win the race first; both paths must converge
    // on the same error.)
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        checkpoint_every: Some(1),
        adaptive: false,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let err = svc
        .run(
            JobRequest::source(LOOP_SRC)
                .bind("chaos_data", dataset(0, 8))
                .faults(
                    FaultPlan::new()
                        .slow_at(0, 1, Duration::from_millis(400))
                        .panic_at(0, 2),
                )
                .deadline(Duration::from_millis(150)),
        )
        .unwrap_err();
    assert!(err.to_string().contains("deadline"), "{err}");
    // No fresh-clock retry marathon: well under a second even with the
    // injected 400ms straggle.
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "deadline did not bound the retry sequence ({:?})",
        t0.elapsed()
    );
    assert_eq!(svc.metrics().get("serve.epochs_recovered"), 0);
    assert_eq!(svc.metrics().get("serve.jobs_completed"), 0);
    // The lane survives and serves the next job.
    let ok = svc.run(JobRequest::source("collect(bag(7), \"after\");")).unwrap();
    assert_eq!(ok.output.collected("after"), &[Value::I64(7)]);
}
