//! End-to-end integration: the paper's evaluation programs through the
//! whole stack (LabyLang/builder → SSA → dataflow → engine) across worker
//! counts and modes, always validated against the single-threaded
//! specification executor.

use labyrinth::baselines::single_thread;
use labyrinth::exec::{run, ExecConfig, ExecMode};
use labyrinth::programs;
use labyrinth::value::Value;
use labyrinth::workload::{PageRankWorkload, VisitCountWorkload};

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

#[test]
fn visit_count_all_executors_agree() {
    let w = VisitCountWorkload {
        days: 6,
        visits_per_day: 3_000,
        num_pages: 128,
        ..Default::default()
    };
    w.register("e2e_vc_");
    let program = programs::visit_count(6, "e2e_vc_");
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let want = multiset(oracle.collected("daily_diffs").to_vec());
    assert_eq!(want.len(), 5);

    let graph = labyrinth::compile(&program).unwrap();
    for workers in [1, 2, 5] {
        for mode in [ExecMode::Pipelined, ExecMode::Barrier] {
            let out =
                run(&graph, &ExecConfig { workers, mode, ..Default::default() }).unwrap();
            assert_eq!(
                multiset(out.collected("daily_diffs").to_vec()),
                want,
                "workers={workers} mode={mode:?}"
            );
        }
    }

    // Separate-jobs executors agree too.
    for cfg in [
        labyrinth::baselines::separate_jobs::SeparateJobsConfig::spark(3),
        labyrinth::baselines::separate_jobs::SeparateJobsConfig::flink(3),
    ] {
        let out = labyrinth::baselines::separate_jobs::run(&program, &cfg).unwrap();
        assert_eq!(multiset(out.collected("daily_diffs").to_vec()), want);
    }
}

#[test]
fn visit_count_with_invariant_join_reuse_and_noreuse_agree() {
    let w = VisitCountWorkload {
        days: 5,
        visits_per_day: 2_000,
        num_pages: 200,
        ..Default::default()
    };
    w.register("e2e_vj_");
    let program = programs::visit_count_with_join(5, "e2e_vj_");
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let want = multiset(oracle.collected("daily_diffs").to_vec());

    let graph = labyrinth::compile(&program).unwrap();
    let reuse = run(&graph, &ExecConfig { workers: 3, ..Default::default() }).unwrap();
    assert_eq!(multiset(reuse.collected("daily_diffs").to_vec()), want);
    assert!(
        reuse.metrics.get("coord.state_reused") > 0,
        "invariant build side should be reused"
    );

    let noreuse = run(
        &graph,
        &ExecConfig { workers: 3, reuse_state: false, ..Default::default() },
    )
    .unwrap();
    assert_eq!(multiset(noreuse.collected("daily_diffs").to_vec()), want);
    assert_eq!(noreuse.metrics.get("coord.state_reused"), 0);
}

#[test]
fn nested_pagerank_agrees_with_oracle() {
    let w = PageRankWorkload {
        days: 2,
        num_pages: 60,
        edges_per_day: 600,
        ..Default::default()
    };
    for day in 1..=2 {
        let edges = w.day_edges(day);
        let pairs: Vec<(usize, usize)> = edges
            .iter()
            .map(|v| (v.key().as_i64() as usize, v.val().as_i64() as usize))
            .collect();
        let mut outdeg = vec![0usize; 60];
        for &(s, _) in &pairs {
            outdeg[s] += 1;
        }
        let adj: Vec<Value> = pairs
            .iter()
            .map(|&(s, d)| {
                Value::pair(
                    Value::I64(s as i64),
                    Value::pair(Value::I64(d as i64), Value::F64(1.0 / outdeg[s] as f64)),
                )
            })
            .collect();
        labyrinth::workload::registry::global().put(format!("e2e_pr_adj{day}"), adj);
    }
    let program = programs::pagerank_nested(2, 8, 60, "e2e_pr_");
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let graph = labyrinth::compile(&program).unwrap();
    let out = run(&graph, &ExecConfig { workers: 3, ..Default::default() }).unwrap();

    // Ranks are floats: compare per (day-order, page) with tolerance.
    let want = oracle.collected("ranks");
    let got = out.collected("ranks");
    assert_eq!(got.len(), want.len());
    let to_map = |vals: &[Value]| {
        let mut m = std::collections::BTreeMap::new();
        for v in vals {
            *m.entry(v.key().as_i64()).or_insert(0.0) += v.val().as_f64();
        }
        m
    };
    let (wm, gm) = (to_map(want), to_map(got));
    for (k, wv) in &wm {
        let gv = gm.get(k).copied().unwrap_or(f64::NAN);
        assert!((gv - wv).abs() < 1e-9, "page {k}: {gv} vs {wv}");
    }
}

#[test]
fn laby_source_files_compile_and_run() {
    // The shipped example programs parse, compile, and (quickstart) run.
    let quickstart = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/programs/quickstart.laby"),
    )
    .unwrap();
    let program = labyrinth::frontend::parse_and_lower(&quickstart).unwrap();
    let graph = labyrinth::compile(&program).unwrap();
    let out = run(&graph, &ExecConfig { workers: 2, ..Default::default() }).unwrap();
    assert_eq!(out.collected("rounds"), &[Value::I64(8)]);

    let vc = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("examples/programs/visit_count.laby"),
    )
    .unwrap();
    let program = labyrinth::frontend::parse_and_lower(&vc).unwrap();
    labyrinth::compile(&program).unwrap(); // compiles; running needs files
}

#[test]
fn write_file_inside_loop_writes_per_step_files() {
    let dir = std::env::temp_dir().join("laby_e2e_write");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let src = r#"
        d = 1;
        while (d <= 3) {
            out = bag(1, 2).map(|v| v * d);
            writeFile(out, "step" + str(d));
            d = d + 1;
        }
    "#;
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let graph = labyrinth::compile(&program).unwrap();
    run(
        &graph,
        &ExecConfig { workers: 2, io_dir: dir.clone(), ..Default::default() },
    )
    .unwrap();
    for d in 1..=3 {
        let content = std::fs::read_to_string(dir.join(format!("step{d}"))).unwrap();
        let mut nums: Vec<i64> =
            content.lines().map(|l| l.parse().unwrap()).collect();
        nums.sort();
        assert_eq!(nums, vec![d, 2 * d]);
    }
}

#[test]
fn empty_loop_zero_iterations() {
    // Loop body never executes; the Φ must select the initial bags.
    let src = r#"
        x = bag(9, 9);
        d = 100;
        while (d <= 3) {
            x = x.map(|v| v + 1);
            d = d + 1;
        }
        collect(x, "x");
    "#;
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let graph = labyrinth::compile(&program).unwrap();
    let out = run(&graph, &ExecConfig { workers: 2, ..Default::default() }).unwrap();
    assert_eq!(multiset(out.collected("x").to_vec()), vec![Value::I64(9), Value::I64(9)]);
}

#[test]
fn deeply_nested_control_flow() {
    let src = r#"
        i = 0;
        total = 0;
        while (i < 3) {
            j = 0;
            while (j < 3) {
                if ((i + j) % 2 == 0) {
                    if (i == j) {
                        total = total + 100;
                    } else {
                        total = total + 10;
                    }
                } else {
                    total = total + 1;
                }
                j = j + 1;
            }
            i = i + 1;
        }
        out = bag(0).map(|z| z + total);
        collect(out, "total");
    "#;
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let graph = labyrinth::compile(&program).unwrap();
    let out = run(&graph, &ExecConfig { workers: 2, ..Default::default() }).unwrap();
    assert_eq!(out.collected("total"), oracle.collected("total"));
    // i==j even: (0,0),(1,1),(2,2) -> 300; other even sums: (0,2),(2,0) -> 20;
    // odd sums: 4 cells -> 4. Total 324.
    assert_eq!(out.collected("total"), &[Value::I64(324)]);
}

#[test]
fn break_exits_loop_early() {
    // Unstructured control flow (§2.2): SSA + the execution-path protocol
    // handle break without special cases.
    let src = r#"
        i = 0;
        acc = bag();
        while (i < 100) {
            cur = bag(1, 2, 3).map(|v| v + i * 10);
            acc = acc.union(cur);
            if (i == 3) {
                break;
            }
            i = i + 1;
        }
        collect(acc, "acc");
        out = bag(0).map(|z| z + i);
        collect(out, "i");
    "#;
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    assert_eq!(oracle.collected("i"), &[Value::I64(3)]);
    assert_eq!(oracle.collected("acc").len(), 12); // 4 iterations x 3
    let graph = labyrinth::compile(&program).unwrap();
    for workers in [1, 3] {
        let out = run(&graph, &ExecConfig { workers, ..Default::default() }).unwrap();
        assert_eq!(
            multiset(out.collected("acc").to_vec()),
            multiset(oracle.collected("acc").to_vec()),
            "workers={workers}"
        );
        assert_eq!(out.collected("i"), oracle.collected("i"));
    }
}

#[test]
fn continue_skips_rest_of_body() {
    let src = r#"
        i = 0;
        acc = bag();
        while (i < 6) {
            i = i + 1;
            if (i % 2 == 0) {
                continue;
            }
            acc = acc.union(bag(0).map(|v| v + i));
        }
        collect(acc, "odds");
    "#;
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    assert_eq!(
        multiset(oracle.collected("odds").to_vec()),
        vec![Value::I64(1), Value::I64(3), Value::I64(5)]
    );
    let graph = labyrinth::compile(&program).unwrap();
    for workers in [1, 2] {
        for mode in [ExecMode::Pipelined, ExecMode::Barrier] {
            let out =
                run(&graph, &ExecConfig { workers, mode, ..Default::default() }).unwrap();
            assert_eq!(
                multiset(out.collected("odds").to_vec()),
                multiset(oracle.collected("odds").to_vec())
            );
        }
    }
}

#[test]
fn break_in_nested_loop_only_exits_inner() {
    let src = r#"
        i = 0;
        total = 0;
        while (i < 3) {
            j = 0;
            while (j < 10) {
                if (j == 2) {
                    break;
                }
                total = total + 1;
                j = j + 1;
            }
            i = i + 1;
        }
        out = bag(0).map(|z| z + total);
        collect(out, "total");
    "#;
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    assert_eq!(oracle.collected("total"), &[Value::I64(6)]); // 3 outer x 2
    let graph = labyrinth::compile(&program).unwrap();
    let out = run(&graph, &ExecConfig { workers: 2, ..Default::default() }).unwrap();
    assert_eq!(out.collected("total"), oracle.collected("total"));
}

#[test]
fn break_outside_loop_rejected() {
    let err = labyrinth::frontend::parse_and_lower("break;").unwrap_err();
    assert!(err.to_string().contains("outside of a loop"), "{err}");
}
