//! The sharded elastic serve tier under mixed-tenant load: weighted-fair
//! admission (a heavy tenant's burst cannot starve a light tenant),
//! elastic pool sizing (grow under backlog, shrink when idle, observable
//! via `lane_widths`), shard-pinned placement (affinity routing keeps
//! preamble replay hits at the single-lane baseline), and the shed
//! contract (`Error::Overloaded` counts `serve.jobs_shed`, never
//! `jobs_failed`), plus cancel + recovery composing per lane under
//! multi-tenant load.

use labyrinth::exec::FaultPlan;
use labyrinth::serve::{JobRequest, JobService, ServeConfig, TenantSpec};
use labyrinth::value::Value;
use labyrinth::Error;
use std::time::{Duration, Instant};

/// A CPU-heavy scalar loop: long enough that a backlog of these is the
/// dominant timescale, short enough for CI.
fn heavy_src(iters: u64) -> String {
    format!("d = 1; while (d <= {iters}) {{ d = d + 1; }} collect(bag(1), \"h\");")
}

const LIGHT_SRC: &str = "v = bag(1, 2, 3); s = v.map(|x| x + 1); collect(s, \"l\");";

/// Weighted-fair admission bounds the light tenant's latency by the jobs
/// DRR actually schedules ahead of it — NOT by the heavy tenant's whole
/// backlog. Identical submission sequence against a FIFO service (no
/// tenants configured) and a fair one; in FIFO the light job completes
/// strictly last, under DRR it overtakes most of the heavy backlog.
#[test]
fn heavy_tenant_cannot_push_light_tenant_past_fairness_bound() {
    let heavy = heavy_src(120_000);
    let run_regime = |tenants: Vec<TenantSpec>| -> (Duration, u64) {
        let fair = !tenants.is_empty();
        let svc = JobService::new(ServeConfig {
            slots: 1,
            workers: 2,
            tenants,
            ..Default::default()
        });
        // Burst the heavy backlog, THEN submit the light job: every job
        // is queued before its template compiles, so all DRR debits are
        // the deterministic default cost.
        let heavy_tickets: Vec<_> = (0..4)
            .map(|_| {
                svc.submit(JobRequest::source(heavy.clone()).tenant("analytics")).unwrap()
            })
            .collect();
        let t0 = Instant::now();
        let light = svc
            .submit(JobRequest::source(LIGHT_SRC).tenant("interactive"))
            .unwrap();
        light.wait().unwrap();
        let light_latency = t0.elapsed();
        // Heavy jobs the lane finished before the light reply (the lane
        // thread records completions in service order).
        let heavy_done_first = if fair {
            svc.metrics().get("serve.tenant.analytics.completed")
        } else {
            // No tenants configured: everything bills the implicit
            // default tenant; subtract the light job itself.
            svc.metrics().get("serve.jobs_completed").saturating_sub(1)
        };
        for t in heavy_tickets {
            t.wait().unwrap();
        }
        (light_latency, heavy_done_first)
    };

    let (fifo_latency, fifo_ahead) = run_regime(Vec::new());
    let (fair_latency, fair_ahead) = run_regime(vec![
        TenantSpec::new("analytics", 1.0),
        TenantSpec::new("interactive", 8.0),
    ]);

    // FIFO: the light job waited out the entire heavy backlog.
    assert_eq!(fifo_ahead, 4, "FIFO must drain every queued heavy job first");
    // Fair: at most the heavy job already running plus the single job
    // one DRR round credits ahead of the light tenant's turn.
    assert!(
        fair_ahead <= 2,
        "DRR let {fair_ahead} heavy jobs ahead of the light tenant (bound: 2)"
    );
    assert!(
        fair_latency < fifo_latency,
        "fair light latency {fair_latency:?} must beat FIFO {fifo_latency:?}"
    );
}

/// Elastic lanes double under sustained backlog (up to `max_workers`)
/// and halve back down after consecutive idle ticks — strictly between
/// job epochs, observable via [`JobService::lane_widths`] and the
/// `serve.pool_grows` / `serve.pool_shrinks` counters.
#[test]
fn pools_grow_under_backlog_and_shrink_when_idle() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 1,
        min_workers: 1,
        max_workers: 4,
        ..Default::default()
    });
    // Lanes publish their starting width asynchronously at spawn.
    let t0 = Instant::now();
    while svc.lane_widths() != vec![1] {
        assert!(t0.elapsed() < Duration::from_secs(10), "lane never published width 1");
        std::thread::sleep(Duration::from_millis(2));
    }
    let src = heavy_src(40_000);
    let tickets: Vec<_> = (0..8)
        .map(|_| svc.submit(JobRequest::source(src.clone())).unwrap())
        .collect();
    let mut max_width = 1;
    for t in tickets {
        t.wait().unwrap();
        max_width = max_width.max(svc.lane_widths()[0]);
    }
    assert!(
        max_width >= 2,
        "sustained 8-job backlog must grow the pool past 1 (saw {max_width})"
    );
    assert!(svc.metrics().get("serve.pool_grows") >= 1);

    // Idle: consecutive 25ms ticks halve the pool back to min_workers.
    let t0 = Instant::now();
    while svc.lane_widths()[0] > 1 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "pool never shrank back to min_workers (width {})",
            svc.lane_widths()[0]
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(svc.metrics().get("serve.pool_shrinks") >= 1);
    // The resized lane still serves correctly.
    let ok = svc.run(JobRequest::source("collect(bag(7), \"z\");")).unwrap();
    assert_eq!(ok.output.collected("z"), &[Value::I64(7)]);
}

/// Loop with an invariant (hoistable, binding-determined) lookup chain —
/// the cross-job preamble-sharing shape from `serve_service.rs`.
const PREAMBLE_SRC: &str = r#"
    d = 1;
    while (d <= 3) {
        attrs = source("fair_attrs").map(|x| pair(x % 8, x));
        v = source("fair_probe").map(|x| pair(x % 8, d));
        j = v.join(attrs);
        t = j.map(|p| snd(snd(p)));
        collect(t, "out");
        d = d + 1;
    }
"#;

/// Shard-pinned placement: with multiple lanes, affinity routing sends
/// repeat submissions of a (program, bound names) group to the lane
/// holding its materialized preamble bags — so the multi-lane service
/// replays exactly as often as a single-lane one. (Before shard pinning,
/// round-robin placement recaptured the bags on every lane.)
#[test]
fn shard_routing_keeps_preamble_hits_at_single_lane_baseline() {
    let attrs: Vec<Value> = (0..8).map(Value::I64).collect();
    let probe: Vec<Value> = (0..16).map(Value::I64).collect();
    let reps = 4;
    let hits_with_slots = |slots: usize| -> (u64, Vec<Value>) {
        let svc = JobService::new(ServeConfig {
            slots,
            workers: 2,
            adaptive: false, // keep revision 0: revisions drop the store
            ..Default::default()
        });
        let mut last = Vec::new();
        for _ in 0..reps {
            let res = svc
                .run(
                    JobRequest::source(PREAMBLE_SRC)
                        .bind("fair_attrs", attrs.clone())
                        .bind("fair_probe", probe.clone()),
                )
                .unwrap();
            last = res.output.collected("out").to_vec();
            last.sort();
        }
        (svc.metrics().get("serve.preamble_hits"), last)
    };
    let (single, out_single) = hits_with_slots(1);
    let (sharded, out_sharded) = hits_with_slots(2);
    assert_eq!(single, reps - 1, "single lane replays every repeat");
    assert!(
        sharded >= single,
        "shard routing must keep preamble hits at the single-lane \
         baseline (sharded {sharded} < single {single})"
    );
    assert_eq!(out_sharded, out_single, "placement must never change results");
    assert!(!out_single.is_empty());
}

/// A tenant over its queued-cost budget is shed at the front door:
/// typed [`Error::Overloaded`] with a retry hint, counted under
/// `serve.jobs_shed` (and the per-tenant counter) — never `jobs_failed`,
/// and never entering the queue.
#[test]
fn shed_requests_count_jobs_shed_never_jobs_failed() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        // Budget covers one default-cost job (1024) but not two.
        tenants: vec![TenantSpec::new("capped", 1.0).budget(1500.0)],
        ..Default::default()
    });
    // Occupy the lane so the capped tenant's backlog stays queued (the
    // budget is enforced against QUEUED cost, which drops at dequeue).
    let blocker = svc.submit(JobRequest::source(heavy_src(150_000))).unwrap();
    let t0 = Instant::now();
    while svc.busy_slots() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "blocker never started");
        std::thread::sleep(Duration::from_millis(2));
    }
    let admitted = svc
        .submit(JobRequest::source(LIGHT_SRC).tenant("capped"))
        .expect("first capped job fits the budget");
    let err = svc
        .submit(JobRequest::source(LIGHT_SRC).tenant("capped"))
        .expect_err("second capped job must shed");
    match err {
        Error::Overloaded { retry_after_ms } => {
            assert!(retry_after_ms > 0, "shed must carry a retry hint");
        }
        other => panic!("expected Error::Overloaded, got: {other}"),
    }
    let m = svc.metrics();
    assert_eq!(m.get("serve.jobs_shed"), 1);
    assert_eq!(m.get("serve.tenant.capped.shed"), 1);
    assert_eq!(m.get("serve.jobs_failed"), 0, "shed is not a failure");
    // The admitted jobs run to completion untouched.
    admitted.wait().unwrap();
    blocker.wait().unwrap();
    assert_eq!(m.get("serve.jobs_failed"), 0);
    assert_eq!(m.get("serve.jobs_shed"), 1, "draining sheds nothing extra");
}

/// Cancellation and fault recovery compose per lane under multi-tenant
/// load: two affinity groups across two lanes, every surviving job
/// carrying a mid-epoch worker panic recovers (never `jobs_failed`),
/// canceled jobs abort, and both lanes stay live.
#[test]
fn cancel_and_recovery_compose_per_lane() {
    let svc = JobService::new(ServeConfig {
        slots: 2,
        workers: 2,
        tenants: vec![
            TenantSpec::new("analytics", 1.0),
            TenantSpec::new("interactive", 4.0),
        ],
        checkpoint_every: Some(1),
        ..Default::default()
    });
    // Two distinct loop programs = two affinity groups; burst group A
    // first so group B's least-loaded fallback takes the other lane.
    let src_a = "v = bag(1, 2, 3); d = 1; s = bag(); while (d <= 3) { s = v.map(|x| x + d); d = d + 1; } collect(s, \"out\");";
    let src_b = "v = bag(4, 5, 6); d = 1; s = bag(); while (d <= 3) { s = v.map(|x| x * d); d = d + 1; } collect(s, \"out\");";
    let mut tickets = Vec::new();
    for (src, tenant) in [(src_a, "analytics"), (src_b, "interactive")] {
        for i in 0..4 {
            let mut req = JobRequest::source(src).tenant(tenant);
            if i % 2 == 0 {
                // Panic worker 1 at superstep 2: mid-epoch, inside the
                // default retry budget.
                req = req.faults(FaultPlan::new().panic_at(1, 2));
            }
            tickets.push((src, i, svc.submit(req).unwrap()));
        }
    }
    // Cancel one job per group (a faulted one, so cancel and recovery
    // race on the same lane). A cancel landing after completion is a
    // no-op, so canceled jobs may legitimately resolve either way.
    for (_, i, t) in &tickets {
        if *i == 2 {
            t.cancel();
        }
    }
    let mut completed = 0;
    let mut canceled = 0;
    for (src, i, t) in tickets {
        match t.wait() {
            Ok(res) => {
                completed += 1;
                let mut got = res.output.collected("out").to_vec();
                got.sort();
                let expect: Vec<i64> = if src == src_a {
                    vec![4, 5, 6] // x + 3 on the final iteration
                } else {
                    vec![12, 15, 18] // x * 3 on the final iteration
                };
                let expect: Vec<Value> = expect.into_iter().map(Value::I64).collect();
                assert_eq!(got, expect, "job {i} of {src:?}");
            }
            Err(e) => {
                assert!(
                    i == 2 && e.to_string().contains("canceled"),
                    "job {i} failed for a non-cancel reason: {e}"
                );
                canceled += 1;
            }
        }
    }
    assert_eq!(completed + canceled, 8, "every ticket resolves");
    assert!(canceled <= 2);
    let m = svc.metrics();
    assert_eq!(m.get("serve.jobs_failed"), 0, "faulted jobs recover, not fail");
    assert!(
        m.get("serve.epochs_recovered") >= 1,
        "at least one surviving faulted job must have recovered"
    );
    // The service survived cancels racing recoveries and is still live.
    let ok = svc.run(JobRequest::source("collect(bag(1), \"z\");")).unwrap();
    assert_eq!(ok.output.collected("z"), &[Value::I64(1)]);
}
