//! Property-based tests of the §6.3 coordination primitives, using the
//! in-repo quickcheck substitute (`labyrinth::util::quickcheck`): random
//! CFG walks are checked against brute-force specifications of input-bag
//! selection, Φ choice, conditional-output decisions, and buffer GC.

use labyrinth::coord::{
    choose_phi_input, input_bag_dead, required_input_len, ExecPath, OutWatcher, SendDecision,
};
use labyrinth::util::quickcheck::{forall, Config, Gen};
use labyrinth::util::rng::Rng;

/// Random walk on the canonical loop CFG:
/// 0 entry -> 1 header -> {2 body, 3 exit}; body -> {4 then, 5 else} -> 6
/// merge -> 1. A walk is a path-shaped block sequence.
fn random_walk(r: &mut Rng) -> Vec<usize> {
    let mut walk = vec![0usize, 1];
    let iters = r.gen_range(6);
    for _ in 0..iters {
        walk.push(2);
        if r.gen_bool(0.5) {
            walk.push(4);
        } else {
            walk.push(5);
        }
        walk.push(6);
        walk.push(1);
    }
    walk.push(3);
    walk
}

fn walk_gen() -> Gen<Vec<i64>> {
    Gen::new(|r: &mut Rng| random_walk(r).into_iter().map(|b| b as i64).collect())
}

fn to_blocks(w: &[i64]) -> Vec<usize> {
    w.iter().map(|&b| b as usize).collect()
}

#[test]
fn required_input_len_is_latest_occurrence() {
    forall(Config::default().cases(200), walk_gen(), |w| {
        let path = to_blocks(w);
        let mut r = Rng::new(w.len() as u64);
        let out_len = 1 + r.gen_range(path.len() as u64) as u32;
        let src = path[r.gen_range(path.len() as u64) as usize];
        match required_input_len(&path, out_len, src) {
            None => !path[..out_len as usize].contains(&src),
            Some(len) => {
                // Spec: the largest i <= out_len with path[i-1] == src.
                let spec = (1..=out_len)
                    .rev()
                    .find(|&i| path[(i - 1) as usize] == src)
                    .unwrap();
                len == spec
            }
        }
    });
}

#[test]
fn phi_choice_picks_globally_latest_input_block() {
    // Φ at merge block 6 with inputs defined in 4 (then) and 5 (else).
    forall(Config::default().cases(200), walk_gen(), |w| {
        let path = to_blocks(w);
        // Every occurrence of 6 is an output bag of the Φ.
        for (i, &b) in path.iter().enumerate() {
            if b != 6 {
                continue;
            }
            let out_len = (i + 1) as u32;
            let Some((chosen, len)) = choose_phi_input(&path, out_len, &[4, 5], 6) else {
                return false;
            };
            // Spec: whichever of blocks 4/5 occurred LAST before out_len —
            // which is exactly the branch taken in this iteration.
            let last4 = path[..i].iter().rposition(|&x| x == 4);
            let last5 = path[..i].iter().rposition(|&x| x == 5);
            let want = match (last4, last5) {
                (Some(a), Some(b)) => {
                    if a > b {
                        (0, (a + 1) as u32)
                    } else {
                        (1, (b + 1) as u32)
                    }
                }
                (Some(a), None) => (0, (a + 1) as u32),
                (None, Some(b)) => (1, (b + 1) as u32),
                (None, None) => return false,
            };
            if (chosen, len) != want {
                return false;
            }
        }
        true
    });
}

#[test]
fn watcher_matches_bruteforce_first_hit() {
    forall(Config::default().cases(300), walk_gen(), |w| {
        let path = to_blocks(w);
        let mut r = Rng::new(w.iter().sum::<i64>() as u64);
        let bag_len = 1 + r.gen_range(path.len() as u64 - 1) as u32;
        let target = path[r.gen_range(path.len() as u64) as usize];
        let blocker = path[r.gen_range(path.len() as u64) as usize];
        if target == blocker {
            return true; // ill-formed edge; the planner never builds this
        }
        let mut watcher = OutWatcher::new(bag_len, target, vec![blocker]);
        for (i, &b) in path.iter().enumerate() {
            watcher.on_block((i + 1) as u32, b);
        }
        let got = watcher.on_final();
        // Brute force: the first position after bag_len hitting either.
        let spec = path
            .iter()
            .enumerate()
            .skip(bag_len as usize)
            .find(|(_, &b)| b == target || b == blocker)
            .map(|(_, &b)| {
                if b == target {
                    SendDecision::Send
                } else {
                    SendDecision::Dead
                }
            })
            .unwrap_or(SendDecision::Dead);
        got == spec
    });
}

/// GC safety: a buffered input bag is never discarded while some
/// not-yet-completed output bag would still select it via the
/// longest-prefix rule.
#[test]
fn input_gc_never_kills_needed_bags() {
    forall(Config::default().cases(300), walk_gen(), |w| {
        let path = to_blocks(w);
        let mut ep = ExecPath::new(7);
        ep.append(0, &path, true);
        let mut r = Rng::new(w.len() as u64 ^ 0xbeef);
        // Consumer at merge block 6; producer at (4 or 5); Φ siblings {4,5}.
        let my_block = 6usize;
        let src_block = if r.gen_bool(0.5) { 4 } else { 5 };
        let supersede = vec![4usize, 5];
        // Pick a random buffered bag: some occurrence of src_block.
        let occs: Vec<u32> = ep.occurrences(src_block).to_vec();
        let Some(&bag_len) = occs.first() else { return true };
        // Progress: outputs processed in order; pick a cut.
        let outs: Vec<u32> = ep.occurrences(my_block).to_vec();
        let cut = r.gen_range(outs.len() as u64 + 1) as usize;
        let min_pending = outs.get(cut).copied();

        let supersede_at = ep.next_occurrence_of_any(&supersede, bag_len);
        let dead = input_bag_dead(bag_len, supersede_at, min_pending, true);
        if !dead {
            return true; // keeping longer is always safe
        }
        // If declared dead, NO remaining output may require bag_len.
        for &out in &outs[cut..] {
            if let Some((_, need)) = choose_phi_input(ep.blocks(), out, &[4, 5], 6) {
                if need == bag_len {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn exec_path_occurrence_index_matches_linear_scan() {
    forall(Config::default().cases(200), walk_gen(), |w| {
        let path = to_blocks(w);
        let mut ep = ExecPath::new(7);
        // Append in random-sized chunks to exercise the broadcast path.
        let mut r = Rng::new(0x5eed ^ w.len() as u64);
        let mut i = 0;
        while i < path.len() {
            let n = 1 + r.gen_range(3) as usize;
            let end = (i + n).min(path.len());
            ep.append(i, &path[i..end], end == path.len());
            i = end;
        }
        for block in 0..7usize {
            for after in 0..path.len() as u32 {
                let got = ep.next_occurrence_after(block, after);
                let spec = path
                    .iter()
                    .enumerate()
                    .map(|(idx, &b)| ((idx + 1) as u32, b))
                    .find(|&(pos, b)| pos > after && b == block)
                    .map(|(pos, _)| pos);
                if got != spec {
                    return false;
                }
            }
        }
        true
    });
}
