//! Chaos matrix: a worker panic injected at EVERY superstep index, for
//! loop programs across worker counts. Every faulted run must recover
//! (bounded retry, resuming from the last superstep-boundary checkpoint
//! when one exists) and complete **byte-identical** to the fault-free
//! run and to the single-threaded oracle — and the recovery itself is
//! verified through the engine's own accounting
//! (`exec.supersteps_recovered` / `exec.supersteps_replayed` and the
//! obs:: Checkpoint/Recover spans), not just the outputs.

use labyrinth::baselines::single_thread;
use labyrinth::exec::{run, ExecConfig, FaultPlan};
use labyrinth::frontend::parse_and_lower;
use labyrinth::obs::{SpanKind, Tracer};
use labyrinth::value::Value;
use std::sync::Arc;
use std::time::Duration;

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

/// The fig6-style counted loop (per-step collect) and a fig7-style loop
/// with an invariant hash-join build side — the state shapes the
/// checkpoint must cover (Φ chain on the driver, reused build state +
/// retained conditional outputs on workers).
fn programs() -> Vec<(&'static str, &'static str, Vec<&'static str>)> {
    vec![
        (
            "counted-loop",
            r#"
            acc = bag();
            i = 0;
            while (i < 5) {
                step = bag(1, 2, 3, 4).map(|v| v * 10 + i);
                if (i % 2 == 0) { acc = acc.union(step); } else { acc = step; }
                collect(step, "steps");
                i = i + 1;
            }
            collect(acc, "acc");
            "#,
            vec!["steps", "acc"],
        ),
        (
            "join-in-loop",
            r#"
            lookup = bag(0, 1, 2, 3, 4).map(|v| pair(v, v * 100));
            acc = bag();
            i = 0;
            while (i < 4) {
                kv = bag(3, 1, 4, 1, 5, 9).map(|v| pair((v + i) % 5, v));
                j = kv.join(lookup).map(|p| fst(snd(p)) + snd(snd(p)));
                acc = acc.union(j);
                i = i + 1;
            }
            collect(acc, "acc");
            "#,
            vec!["acc"],
        ),
    ]
}

#[test]
fn panic_at_every_superstep_recovers_identically() {
    for (name, src, labels) in programs() {
        let program = parse_and_lower(src).unwrap_or_else(|e| panic!("{name}: {e}"));
        let oracle = single_thread::run(&program, &Default::default())
            .unwrap_or_else(|e| panic!("{name} oracle: {e}"));
        let graph = labyrinth::compile(&program).unwrap_or_else(|e| panic!("{name}: {e}"));

        for workers in [1usize, 2, 4] {
            // Fault-free reference (explicitly unfaulted so the matrix is
            // deterministic even under a LABY_FAULTS chaos-smoke leg).
            let clean = run(
                &graph,
                &ExecConfig { workers, faults: None, ..Default::default() },
            )
            .unwrap_or_else(|e| panic!("{name} w={workers} clean: {e}"));
            let path_len = clean.path_len as u32;
            assert!(path_len > 1, "{name}: loop program must take multiple supersteps");

            let mut recoveries = 0u32;
            for k in 1..=path_len {
                let victim = (k as usize) % workers;
                let tracer = Arc::new(Tracer::new(true));
                let cfg = ExecConfig {
                    workers,
                    checkpoint_every: Some(1),
                    faults: Some(Arc::new(FaultPlan::new().panic_at(victim, k))),
                    trace: Some(tracer.clone()),
                    // Keep a wedged retry from hanging the suite.
                    stall_timeout: Duration::from_secs(30),
                    ..Default::default()
                };
                let out = run(&graph, &cfg)
                    .unwrap_or_else(|e| panic!("{name} w={workers} panic@{k}: {e}"));

                // Byte-identical results vs the fault-free run AND the
                // single-thread spec.
                for label in &labels {
                    let got = multiset(out.collected(label).to_vec());
                    assert_eq!(
                        got,
                        multiset(clean.collected(label).to_vec()),
                        "{name} w={workers} panic@{k} label {label}: diverged from fault-free"
                    );
                    assert_eq!(
                        got,
                        multiset(oracle.collected(label).to_vec()),
                        "{name} w={workers} panic@{k} label {label}: diverged from oracle"
                    );
                }
                assert_eq!(out.path_len as u32, path_len, "{name} w={workers} panic@{k}");

                // The injected fault really fired and was really retried.
                assert_eq!(
                    out.metrics.get("exec.faults_injected"),
                    1,
                    "{name} w={workers} panic@{k}: fault did not fire"
                );
                assert_eq!(
                    out.metrics.get("exec.epoch_retries"),
                    1,
                    "{name} w={workers} panic@{k}: expected exactly one retry"
                );

                // Recovery accounting: a resumed attempt skipped the
                // checkpointed prefix and executed only the rest.
                let recovered = out.metrics.get("exec.supersteps_recovered");
                let replayed = out.metrics.get("exec.supersteps_replayed");
                if recovered > 0 {
                    recoveries += 1;
                    assert_eq!(
                        recovered + replayed,
                        path_len as u64,
                        "{name} w={workers} panic@{k}: prefix + replay must cover the path"
                    );
                    // (`exec.checkpoints_taken` is per-attempt and the
                    // surviving attempt may take none — the resume itself,
                    // plus the Checkpoint span from the faulted attempt
                    // below, prove a checkpoint was cut.)
                    // The resumed attempt announces itself in the trace.
                    let trace = tracer.take();
                    assert!(
                        trace
                            .events
                            .iter()
                            .any(|e| matches!(e.kind, SpanKind::Recover { pos } if pos as u64 == recovered)),
                        "{name} w={workers} panic@{k}: no Recover span at pos {recovered}"
                    );
                    assert!(
                        trace.events.iter().any(|e| matches!(e.kind, SpanKind::Checkpoint { .. })),
                        "{name} w={workers} panic@{k}: no Checkpoint span"
                    );
                }
            }
            // With checkpoint_every=1 every decision boundary is cut, so
            // any panic past the first cut resumes from a checkpoint —
            // the matrix must exercise genuine resume, not only
            // retry-from-scratch.
            assert!(
                recoveries > 0,
                "{name} w={workers}: no superstep index led to a checkpoint resume"
            );
        }
    }
}

#[test]
fn dropped_message_stalls_then_recovers() {
    // A DropData fault starves a consumer; the driver's stall timeout
    // converts the hang into a retryable coordination error and the
    // retry completes with correct output.
    let src = r#"
        acc = bag();
        i = 0;
        while (i < 3) {
            acc = acc.union(bag(1, 2, 3).map(|v| v + i));
            i = i + 1;
        }
        collect(acc, "acc");
    "#;
    let program = parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let graph = labyrinth::compile(&program).unwrap();
    let cfg = ExecConfig {
        workers: 2,
        checkpoint_every: Some(1),
        faults: Some(Arc::new(FaultPlan::new().drop_at(0, 2))),
        stall_timeout: Duration::from_millis(400),
        ..Default::default()
    };
    let out = run(&graph, &cfg).unwrap();
    assert_eq!(
        multiset(out.collected("acc").to_vec()),
        multiset(oracle.collected("acc").to_vec())
    );
    assert_eq!(out.metrics.get("exec.faults_injected"), 1);
    assert!(out.metrics.get("exec.epoch_retries") >= 1);
}

#[test]
fn slow_worker_is_not_an_error() {
    // A straggler delays but never fails the epoch: no retry, same
    // output.
    let src = r#"
        acc = bag();
        i = 0;
        while (i < 3) {
            acc = acc.union(bag(7, 8).map(|v| v * (i + 1)));
            i = i + 1;
        }
        collect(acc, "acc");
    "#;
    let program = parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let graph = labyrinth::compile(&program).unwrap();
    let cfg = ExecConfig {
        workers: 2,
        faults: Some(Arc::new(
            FaultPlan::new().slow_at(1, 2, Duration::from_millis(50)),
        )),
        ..Default::default()
    };
    let out = run(&graph, &cfg).unwrap();
    assert_eq!(
        multiset(out.collected("acc").to_vec()),
        multiset(oracle.collected("acc").to_vec())
    );
    assert_eq!(out.metrics.get("exec.faults_injected"), 1);
    assert_eq!(out.metrics.get("exec.epoch_retries"), 0);
}
