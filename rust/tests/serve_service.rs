//! Integration tests for the `serve::` job service: concurrent
//! submission correctness against one-shot `run_plan`, cache-key
//! separation, worker-pool reuse across epochs, clean state between
//! jobs (no §7 `reuse_state` bleed across tenants), and adaptive
//! template revision.

use labyrinth::exec::{ExecConfig, ExecMode};
use labyrinth::serve::{CacheOutcome, JobRequest, JobService, ServeConfig};
use labyrinth::value::Value;
use std::sync::Arc;
use std::time::Duration;

/// The distinct programs the stress test serves. Each collects under
/// label "out" and depends on a per-request dataset named `stress_data`.
const PROGRAMS: &[&str] = &[
    "v = source(\"stress_data\"); o = v.map(|x| x * 2); collect(o, \"out\");",
    "v = source(\"stress_data\"); k = v.map(|x| pair(x % 4, x)); o = k.reduceByKey(|a, b| a + b); collect(o, \"out\");",
    "v = source(\"stress_data\"); d = 1; s = bag(); while (d <= 3) { s = v.map(|x| x + d); d = d + 1; } collect(s, \"out\");",
];

fn dataset(seed: i64, len: i64) -> Vec<Value> {
    (0..len).map(|i| Value::I64(seed + i)).collect()
}

/// One-shot oracle: compile + run with the dataset registered in an
/// isolated overlay registry (never the global one).
fn one_shot(src: &str, data: Vec<Value>, workers: usize) -> Vec<Value> {
    let reg = Arc::new(labyrinth::workload::registry::Registry::new());
    reg.put("stress_data", data);
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let (graph, _) = labyrinth::compile_with_registry(
        &program,
        &labyrinth::opt::OptConfig::default(),
        &reg,
    )
    .unwrap();
    let out = labyrinth::exec::run(
        &graph,
        &ExecConfig { workers, registry: reg, ..Default::default() },
    )
    .unwrap();
    let mut got = out.collected("out").to_vec();
    got.sort();
    got
}

#[test]
fn concurrent_stress_matches_single_shot() {
    const CLIENTS: usize = 4;
    const JOBS_PER_CLIENT: usize = 6;
    let svc = Arc::new(JobService::new(ServeConfig {
        slots: 2,
        workers: 2,
        ..Default::default()
    }));
    // Expected outputs per (program, seed) pair, computed one-shot.
    let expected: Vec<Vec<Vec<Value>>> = (0..CLIENTS)
        .map(|c| {
            (0..JOBS_PER_CLIENT)
                .map(|j| {
                    let src = PROGRAMS[(c + j) % PROGRAMS.len()];
                    one_shot(src, dataset((c * 100 + j) as i64, 16), 2)
                })
                .collect()
        })
        .collect();

    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let svc = svc.clone();
            let expected = &expected;
            s.spawn(move || {
                for j in 0..JOBS_PER_CLIENT {
                    let src = PROGRAMS[(c + j) % PROGRAMS.len()];
                    let res = svc
                        .run(
                            JobRequest::source(src)
                                .bind("stress_data", dataset((c * 100 + j) as i64, 16)),
                        )
                        .unwrap();
                    let mut got = res.output.collected("out").to_vec();
                    got.sort();
                    assert_eq!(got, expected[c][j], "client {c} job {j} ({src})");
                }
            });
        }
    });

    let m = svc.metrics();
    assert_eq!(m.get("serve.jobs_completed"), (CLIENTS * JOBS_PER_CLIENT) as u64);
    assert_eq!(m.get("serve.jobs_failed"), 0);
    // K distinct programs -> exactly K templates compiled (revisions are
    // not misses); everything else hit the cache.
    assert_eq!(m.get("serve.cache_misses"), PROGRAMS.len() as u64);
    assert!(
        m.get("serve.cache_hits") + m.get("serve.cache_revisions")
            >= (CLIENTS * JOBS_PER_CLIENT - PROGRAMS.len()) as u64
    );
}

#[test]
fn cache_key_separates_opt_configs_and_results_agree() {
    let svc = JobService::new(ServeConfig { slots: 1, adaptive: false, ..Default::default() });
    let src = "v = source(\"stress_data\"); d = 1; s = bag(); while (d <= 3) { s = v.map(|x| x + d); d = d + 1; } collect(s, \"out\");";
    let data = || dataset(7, 12);

    let optimized = svc.run(JobRequest::source(src).bind("stress_data", data())).unwrap();
    assert_eq!(optimized.cache, CacheOutcome::Miss);
    let unoptimized = svc
        .run(
            JobRequest::source(src)
                .bind("stress_data", data())
                .opt(labyrinth::opt::OptConfig::none()),
        )
        .unwrap();
    assert_eq!(
        unoptimized.cache,
        CacheOutcome::Miss,
        "differing opt flags must not share a template"
    );
    assert_eq!(svc.cache().misses(), 2);

    // Same answers from both templates.
    let mut a = optimized.output.collected("out").to_vec();
    let mut b = unoptimized.output.collected("out").to_vec();
    a.sort();
    b.sort();
    assert_eq!(a, b);

    // Resubmitting each hits its own entry.
    let r1 = svc.run(JobRequest::source(src).bind("stress_data", data())).unwrap();
    assert_eq!(r1.cache, CacheOutcome::Hit);
    let r2 = svc
        .run(
            JobRequest::source(src)
                .bind("stress_data", data())
                .opt(labyrinth::opt::OptConfig::none()),
        )
        .unwrap();
    assert_eq!(r2.cache, CacheOutcome::Hit);
    assert_eq!(svc.cache().misses(), 2, "no recompiles on the hit path");
}

#[test]
fn pool_threads_are_reused_across_jobs() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 3,
        adaptive: false,
        ..Default::default()
    });
    const JOBS: usize = 8;
    for i in 0..JOBS {
        let res = svc
            .run(
                JobRequest::source(
                    "v = source(\"stress_data\"); o = v.map(|x| x + 1); collect(o, \"out\");",
                )
                .bind("stress_data", dataset(i as i64, 8)),
            )
            .unwrap();
        assert_eq!(res.output.collected("out").len(), 8);
    }
    // Every job ran as ONE epoch per resident worker — no thread churn
    // (thread-identity stability is asserted in exec::pool's unit tests;
    // the epoch count proves the service reuses one pool). Under a
    // LABY_FAULTS chaos leg injected panics add retry epochs on the SAME
    // pool, so the count becomes a floor instead of an exact match.
    if labyrinth::exec::default_faults().is_some() {
        assert!(svc.metrics().get("serve.pool_epochs") >= (JOBS * 3) as u64);
    } else {
        assert_eq!(svc.metrics().get("serve.pool_epochs"), (JOBS * 3) as u64);
    }
}

#[test]
fn no_state_bleeds_between_jobs_with_reuse_on() {
    // A loop-invariant hash-join build side is kept across STEPS within
    // a job (§7 reuse). Two tenants submit the same cached template with
    // different build-side data; the second result must reflect ONLY the
    // second tenant's data — a stale hash table from the first epoch
    // would join against tenant A's attributes.
    let src = r#"
        attrs = source("tenant_attrs");
        d = 1;
        while (d <= 3) {
            v = source("tenant_probe").map(|x| pair(x, d));
            j = attrs.join(v);
            t = j.map(|p| fst(snd(p)));
            collect(t, "out");
            d = d + 1;
        }
    "#;
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        reuse_state: true,
        ..Default::default()
    });
    let attrs_a: Vec<Value> = (0..8).map(|k| Value::pair(Value::I64(k), Value::I64(k))).collect();
    let attrs_b: Vec<Value> =
        (0..8).map(|k| Value::pair(Value::I64(k), Value::I64(k + 1000))).collect();
    let probe: Vec<Value> = (0..8).map(Value::I64).collect();

    let run_with = |attrs: &[Value]| -> i64 {
        let res = svc
            .run(
                JobRequest::source(src)
                    .bind("tenant_attrs", attrs.to_vec())
                    .bind("tenant_probe", probe.clone()),
            )
            .unwrap();
        res.output.collected("out").iter().map(|v| v.as_i64()).sum()
    };
    let sum_a = run_with(&attrs_a);
    let sum_b = run_with(&attrs_b);
    // A: payloads 0..8 summed over 3 steps; B: payloads 1000..1008.
    assert_eq!(sum_a, 3 * (0..8).sum::<i64>());
    assert_eq!(sum_b, 3 * (1000..1008).sum::<i64>(), "tenant B saw tenant A's build table");
}

#[test]
fn adaptive_revision_fires_and_stays_correct() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        adaptive: true,
        ..Default::default()
    });
    // The filter keeps everything at runtime (observed selectivity 1.0
    // vs the static 0.25 guess), so recorded stats drift from the
    // estimates the first compile used.
    let src = "v = source(\"adapt_data\"); f = v.filter(|x| x >= 0); k = f.map(|x| pair(x % 4, x)); o = k.reduceByKey(|a, b| a + b); collect(o, \"out\");";
    let data = || dataset(0, 64);
    let want = one_shot(src, data(), 2);

    let r1 = svc.run(JobRequest::source(src).bind("adapt_data", data())).unwrap();
    assert_eq!(r1.cache, CacheOutcome::Miss);
    let r2 = svc.run(JobRequest::source(src).bind("adapt_data", data())).unwrap();
    assert_eq!(r2.cache, CacheOutcome::Revised, "observed stats trigger a revision");
    assert_eq!(r2.revision, 1);
    assert_eq!(svc.cache().revisions(), 1);
    for r in [r1, r2] {
        let mut got = r.output.collected("out").to_vec();
        got.sort();
        assert_eq!(got, want, "revisions preserve semantics");
    }
    // The revision converges: stats from the revised plan match what it
    // was optimized with, so the third submission is a plain hit.
    let r3 = svc.run(JobRequest::source(src).bind("adapt_data", data())).unwrap();
    assert_eq!(r3.cache, CacheOutcome::Hit, "no oscillating re-optimization");
}

#[test]
fn barrier_mode_service_matches_pipelined() {
    let src = "v = source(\"stress_data\"); d = 1; s = bag(); while (d <= 4) { s = v.map(|x| x * d); d = d + 1; } collect(s, \"out\");";
    let pipelined = JobService::new(ServeConfig { slots: 1, ..Default::default() });
    let barrier =
        JobService::new(ServeConfig { slots: 1, mode: ExecMode::Barrier, ..Default::default() });
    let a = pipelined
        .run(JobRequest::source(src).bind("stress_data", dataset(1, 10)))
        .unwrap();
    let b = barrier
        .run(JobRequest::source(src).bind("stress_data", dataset(1, 10)))
        .unwrap();
    let mut av = a.output.collected("out").to_vec();
    let mut bv = b.output.collected("out").to_vec();
    av.sort();
    bv.sort();
    assert_eq!(av, bv);
}

#[test]
fn canceled_queued_job_never_runs() {
    // One slot busy with a slow job; a queued job canceled before the
    // lane reaches it must fail with a cancellation error.
    let svc = JobService::new(ServeConfig { slots: 1, workers: 2, ..Default::default() });
    let slow = svc
        .submit(JobRequest::source(
            "d = 1; while (d <= 3000) { d = d + 1; } collect(bag(1), \"x\");",
        ))
        .unwrap();
    let victim = svc.submit(JobRequest::source("collect(bag(2), \"y\");")).unwrap();
    victim.cancel();
    let err = victim.wait().unwrap_err();
    assert!(err.to_string().contains("canceled"), "{err}");
    assert!(slow.wait().is_ok());
    assert_eq!(svc.metrics().get("serve.jobs_canceled"), 1);
}

/// A program that runs for tens of seconds if nothing aborts it (the
/// deadline test's 2M-iteration loop already exceeds 150ms by orders of
/// magnitude; 20M bounds the no-abort runtime well past every assertion
/// window below).
const VERY_LONG: &str = "d = 1; while (d <= 20000000) { d = d + 1; } collect(bag(1), \"x\");";

/// Wait (bounded) until the service has picked the job up off the queue.
fn wait_until_running(svc: &JobService) {
    let t0 = std::time::Instant::now();
    while svc.busy_slots() == 0 || svc.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "job never started running");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn cancel_mid_run_aborts_promptly_and_pool_is_reusable() {
    let svc = JobService::new(ServeConfig { slots: 1, workers: 2, ..Default::default() });
    let ticket = svc.submit(JobRequest::source(VERY_LONG)).unwrap();
    wait_until_running(&svc);
    // Give the epoch a moment of real execution before pulling the plug.
    std::thread::sleep(Duration::from_millis(50));
    let t0 = std::time::Instant::now();
    ticket.cancel();
    let err = ticket.wait().unwrap_err();
    let abort_latency = t0.elapsed();
    assert!(err.to_string().contains("canceled"), "{err}");
    // Cooperative abort is bounded by a superstep + the driver's cancel
    // poll — far below the tens of seconds the loop would otherwise run.
    assert!(
        abort_latency < Duration::from_secs(5),
        "cancel took {abort_latency:?}; mid-run cancel is not taking effect"
    );
    assert_eq!(svc.metrics().get("serve.jobs_canceled"), 1);
    // The same slot (and its resident pool) serves the next job cleanly.
    let ok = svc.run(JobRequest::source("collect(bag(3), \"z\");")).unwrap();
    assert_eq!(ok.output.collected("z"), &[Value::I64(3)]);
}

#[test]
fn cancel_after_completion_is_a_noop() {
    let svc = JobService::new(ServeConfig { slots: 1, workers: 2, ..Default::default() });
    let ticket = svc.submit(JobRequest::source("collect(bag(4), \"done\");")).unwrap();
    // Let the job finish (result parked in the ticket's channel).
    let t0 = std::time::Instant::now();
    while svc.busy_slots() > 0 || svc.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "quick job never finished");
        std::thread::sleep(Duration::from_millis(2));
    }
    ticket.cancel();
    let res = ticket.wait().expect("cancel after completion must not void the result");
    assert_eq!(res.output.collected("done"), &[Value::I64(4)]);
    assert_eq!(svc.metrics().get("serve.jobs_canceled"), 0);
    // Service unaffected.
    assert!(svc.run(JobRequest::source("collect(bag(5), \"ok\");")).is_ok());
}

#[test]
fn deadline_firing_while_canceling_still_tears_down_cleanly() {
    let svc = JobService::new(ServeConfig { slots: 1, workers: 2, ..Default::default() });
    let ticket = svc
        .submit(JobRequest::source(VERY_LONG).deadline(Duration::from_millis(120)))
        .unwrap();
    wait_until_running(&svc);
    // Cancel right around when the deadline fires: whichever path wins,
    // the job must abort with a clean teardown.
    std::thread::sleep(Duration::from_millis(100));
    ticket.cancel();
    let err = ticket.wait().unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("canceled") || msg.contains("deadline"),
        "unexpected abort reason: {msg}"
    );
    // The pool survived the racing aborts and serves the next job.
    let ok = svc.run(JobRequest::source("collect(bag(6), \"after\");")).unwrap();
    assert_eq!(ok.output.collected("after"), &[Value::I64(6)]);
}

#[test]
fn deadline_bounds_a_running_job() {
    let svc = JobService::new(ServeConfig { slots: 1, workers: 2, ..Default::default() });
    // A genuinely long job (tens of thousands of coordination steps)
    // with a tight running deadline must abort rather than run to
    // completion — and the lane must stay usable afterwards.
    let err = svc
        .run(
            JobRequest::source(
                "d = 1; while (d <= 2000000) { d = d + 1; } collect(bag(1), \"x\");",
            )
            .deadline(Duration::from_millis(150)),
        )
        .unwrap_err();
    assert!(err.to_string().contains("deadline"), "{err}");
    let ok = svc.run(JobRequest::source("collect(bag(3), \"z\");")).unwrap();
    assert_eq!(ok.output.collected("z"), &[Value::I64(3)]);
}

/// Loop with an invariant (hoistable, binding-determined) lookup chain
/// and a varying probe side — the cross-job preamble-sharing shape.
const PREAMBLE_SRC: &str = r#"
    d = 1;
    while (d <= 3) {
        attrs = source("pre_attrs").map(|x| pair(x % 8, x));
        v = source("pre_probe").map(|x| pair(x % 8, d));
        j = v.join(attrs);
        t = j.map(|p| snd(snd(p)));
        collect(t, "out");
        d = d + 1;
    }
"#;

fn preamble_oracle(attrs: Vec<Value>, probe: Vec<Value>) -> Vec<Value> {
    let reg = Arc::new(labyrinth::workload::registry::Registry::new());
    reg.put("pre_attrs", attrs);
    reg.put("pre_probe", probe);
    let program = labyrinth::frontend::parse_and_lower(PREAMBLE_SRC).unwrap();
    let (graph, _) = labyrinth::compile_with_registry(
        &program,
        &labyrinth::opt::OptConfig::default(),
        &reg,
    )
    .unwrap();
    let out = labyrinth::exec::run(
        &graph,
        &ExecConfig { workers: 2, registry: reg, ..Default::default() },
    )
    .unwrap();
    let mut got = out.collected("out").to_vec();
    got.sort();
    got
}

#[test]
fn preamble_sharing_replays_identical_bindings_and_recomputes_changed_ones() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        adaptive: false, // keep the template at revision 0 for this test
        ..Default::default()
    });
    // Same join keys (x % 8) under both bindings, different payloads —
    // a stale replay of tenant A's bags would be VISIBLE in B's output.
    let attrs_a: Vec<Value> = (0..8).map(Value::I64).collect();
    let attrs_b: Vec<Value> = (96..104).map(Value::I64).collect();
    let probe: Vec<Value> = (0..16).map(Value::I64).collect();
    let run_with = |attrs: &[Value]| -> Vec<Value> {
        let res = svc
            .run(
                JobRequest::source(PREAMBLE_SRC)
                    .bind("pre_attrs", attrs.to_vec())
                    .bind("pre_probe", probe.clone()),
            )
            .unwrap();
        let mut got = res.output.collected("out").to_vec();
        got.sort();
        got
    };
    let want_a = preamble_oracle(attrs_a.clone(), probe.clone());
    let want_b = preamble_oracle(attrs_b.clone(), probe.clone());
    assert_ne!(want_a, want_b, "test premise: the binding change is observable");

    // First submission materializes; the identical second one replays.
    assert_eq!(run_with(&attrs_a), want_a);
    assert_eq!(svc.metrics().get("serve.preamble_hits"), 0);
    assert_eq!(run_with(&attrs_a), want_a, "replayed run must be byte-identical");
    assert_eq!(svc.metrics().get("serve.preamble_hits"), 1);

    // A changed binding signature must NOT replay tenant A's bags.
    assert_eq!(run_with(&attrs_b), want_b, "changed bindings must recompute");
    assert_eq!(svc.metrics().get("serve.preamble_hits"), 1);

    // Both fingerprints are now materialized; each replays its own.
    assert_eq!(run_with(&attrs_a), want_a);
    assert_eq!(run_with(&attrs_b), want_b);
    assert_eq!(svc.metrics().get("serve.preamble_hits"), 3);
}

#[test]
fn preamble_sharing_can_be_disabled() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        adaptive: false,
        share_preambles: false,
        ..Default::default()
    });
    let attrs: Vec<Value> = (0..8).map(Value::I64).collect();
    let probe: Vec<Value> = (0..16).map(Value::I64).collect();
    for _ in 0..2 {
        let res = svc
            .run(
                JobRequest::source(PREAMBLE_SRC)
                    .bind("pre_attrs", attrs.clone())
                    .bind("pre_probe", probe.clone()),
            )
            .unwrap();
        assert!(!res.output.collected("out").is_empty());
    }
    assert_eq!(svc.metrics().get("serve.preamble_hits"), 0);
}

#[test]
fn adaptive_revision_invalidates_shared_preambles() {
    // With adaptive on, the second identical submission usually revises
    // (observed rows vs model guesses). A revision is a NEW template:
    // its preamble store either starts empty or — when the preamble
    // subgraph is structurally unchanged — carries remapped entries.
    // Either way, every post-revision run must produce exact results
    // (a stale replay of the wrong plan's node ids would not).
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        adaptive: true,
        ..Default::default()
    });
    let attrs: Vec<Value> = (0..8).map(Value::I64).collect();
    let probe: Vec<Value> = (0..16).map(Value::I64).collect();
    let want = preamble_oracle(attrs.clone(), probe.clone());
    for i in 0..4 {
        let res = svc
            .run(
                JobRequest::source(PREAMBLE_SRC)
                    .bind("pre_attrs", attrs.clone())
                    .bind("pre_probe", probe.clone()),
            )
            .unwrap();
        let mut got = res.output.collected("out").to_vec();
        got.sort();
        assert_eq!(got, want, "submission {i} (cache {:?})", res.cache);
    }
}

#[test]
fn revision_with_unchanged_preamble_still_replays() {
    // An adaptive revision driven by IN-LOOP drift (a filter that keeps
    // everything vs the model's 0.25 guess) leaves the hoisted,
    // binding-determined preamble subgraph structurally unchanged. The
    // materialized preamble bags must be CARRIED across the revision and
    // replayed by later identical submissions — not recomputed (the
    // pre-carry behavior dropped the store on every revision).
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        adaptive: true,
        ..Default::default()
    });
    let src = r#"
        d = 1;
        while (d <= 3) {
            attrs = source("xrev_attrs").map(|x| pair(x % 8, x));
            v = source("xrev_probe").map(|x| pair(x % 8, d)).filter(|p| fst(p) >= 0);
            j = v.join(attrs);
            t = j.map(|p| snd(snd(p)));
            collect(t, "out");
            d = d + 1;
        }
    "#;
    let attrs: Vec<Value> = (0..8).map(Value::I64).collect();
    let probe: Vec<Value> = (0..16).map(Value::I64).collect();
    let run = || -> Vec<Value> {
        let res = svc
            .run(
                JobRequest::source(src)
                    .bind("xrev_attrs", attrs.clone())
                    .bind("xrev_probe", probe.clone()),
            )
            .unwrap();
        let mut got = res.output.collected("out").to_vec();
        got.sort();
        got
    };
    let want = run(); // Miss: materializes + stores the preamble bags.
    for i in 0..3 {
        assert_eq!(run(), want, "submission {}", i + 1);
    }
    assert!(
        svc.cache().revisions() >= 1,
        "test premise: the in-loop filter's drift forces a revision"
    );
    assert!(
        svc.cache().preambles_carried() >= 1,
        "structurally unchanged preamble store must survive the revision"
    );
    assert!(
        svc.metrics().get("serve.preamble_hits") >= 1,
        "carried preamble bags must replay after the revision"
    );
}

#[test]
fn fused_feedback_reaches_recompile_and_converges() {
    // The filter keeps everything (vs the 0.25 static guess) and fuses
    // with the downstream map. The revision must see the observed rows
    // pinned onto BOTH pre-fusion nodes (lineage back-mapping), and the
    // revised template must converge — no revision oscillation.
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        adaptive: true,
        ..Default::default()
    });
    let src = "v = source(\"fusefb_data\"); f = v.filter(|x| x >= 0); k = f.map(|x| pair(x % 4, x)); o = k.reduceByKey(|a, b| a + b); collect(o, \"out\");";
    let data = || dataset(0, 64);
    let want = one_shot(src, data(), 2);

    let r1 = svc.run(JobRequest::source(src).bind("fusefb_data", data())).unwrap();
    assert_eq!(r1.cache, CacheOutcome::Miss);
    let r2 = svc.run(JobRequest::source(src).bind("fusefb_data", data())).unwrap();
    assert_eq!(r2.cache, CacheOutcome::Revised, "drifted stats trigger a revision");
    // The revised compile ran with feedback: the fused chain's observed
    // rows were pinned under the pre-fusion names (filter AND map), not
    // just the surviving tail — `opt.feedback_rows_pinned` counts pinned
    // nodes on the FRESH (pre-fusion) graph.
    assert!(
        r2.output.metrics.get("opt.feedback_rows_pinned") >= 2,
        "interior chain members' stats must survive fusion into the recompile (got {})",
        r2.output.metrics.get("opt.feedback_rows_pinned")
    );
    for r in [r1, r2] {
        let mut got = r.output.collected("out").to_vec();
        got.sort();
        assert_eq!(got, want, "revisions preserve semantics");
    }
    let r3 = svc.run(JobRequest::source(src).bind("fusefb_data", data())).unwrap();
    assert_eq!(r3.cache, CacheOutcome::Hit, "fused template converges under feedback");
}

#[test]
fn interior_stage_counters_reach_recompile_and_converge() {
    // map → filter → map fuses into one chain whose HEAD map sits beyond
    // the filter boundary: its cardinality cannot be recovered from the
    // fused tail's output count (the old lineage walk stopped at the
    // filter). The per-stage runtime counters in `FusedT` carry measured
    // rows for every interior stage into the recompile; the revised
    // template must converge — no revision oscillation — and preserve
    // semantics throughout.
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        adaptive: true,
        ..Default::default()
    });
    let src = "v = source(\"intfb_data\"); a = v.map(|x| x + 1); f = a.filter(|x| x % 2 == 0); t = f.map(|x| pair(x % 4, x)); o = t.reduceByKey(|p, q| p + q); collect(o, \"out\");";
    let data = || dataset(0, 64);
    let want = {
        let reg = Arc::new(labyrinth::workload::registry::Registry::new());
        reg.put("intfb_data", data());
        let program = labyrinth::frontend::parse_and_lower(src).unwrap();
        let (graph, _) = labyrinth::compile_with_registry(
            &program,
            &labyrinth::opt::OptConfig::default(),
            &reg,
        )
        .unwrap();
        let out = labyrinth::exec::run(
            &graph,
            &ExecConfig { workers: 2, registry: reg, ..Default::default() },
        )
        .unwrap();
        let mut got = out.collected("out").to_vec();
        got.sort();
        got
    };

    let r1 = svc.run(JobRequest::source(src).bind("intfb_data", data())).unwrap();
    assert_eq!(r1.cache, CacheOutcome::Miss);
    let r2 = svc.run(JobRequest::source(src).bind("intfb_data", data())).unwrap();
    assert_eq!(r2.cache, CacheOutcome::Revised, "drifted interior stats trigger a revision");
    // The recompile saw measured rows pinned for the whole pre-fusion
    // chain — head map AND filter AND tail — not just the surviving tail.
    assert!(
        r2.output.metrics.get("opt.feedback_rows_pinned") >= 3,
        "interior stages beyond the filter boundary must reach the recompile (got {})",
        r2.output.metrics.get("opt.feedback_rows_pinned")
    );
    for r in [r1, r2] {
        let mut got = r.output.collected("out").to_vec();
        got.sort();
        assert_eq!(got, want, "revisions preserve semantics");
    }
    let r3 = svc.run(JobRequest::source(src).bind("intfb_data", data())).unwrap();
    assert_eq!(r3.cache, CacheOutcome::Hit, "per-stage pins converge");
}
