//! Integration tests for the `serve::` job service: concurrent
//! submission correctness against one-shot `run_plan`, cache-key
//! separation, worker-pool reuse across epochs, clean state between
//! jobs (no §7 `reuse_state` bleed across tenants), and adaptive
//! template revision.

use labyrinth::exec::{ExecConfig, ExecMode};
use labyrinth::serve::{CacheOutcome, JobRequest, JobService, ServeConfig};
use labyrinth::value::Value;
use std::sync::Arc;
use std::time::Duration;

/// The distinct programs the stress test serves. Each collects under
/// label "out" and depends on a per-request dataset named `stress_data`.
const PROGRAMS: &[&str] = &[
    "v = source(\"stress_data\"); o = v.map(|x| x * 2); collect(o, \"out\");",
    "v = source(\"stress_data\"); k = v.map(|x| pair(x % 4, x)); o = k.reduceByKey(|a, b| a + b); collect(o, \"out\");",
    "v = source(\"stress_data\"); d = 1; s = bag(); while (d <= 3) { s = v.map(|x| x + d); d = d + 1; } collect(s, \"out\");",
];

fn dataset(seed: i64, len: i64) -> Vec<Value> {
    (0..len).map(|i| Value::I64(seed + i)).collect()
}

/// One-shot oracle: compile + run with the dataset registered in an
/// isolated overlay registry (never the global one).
fn one_shot(src: &str, data: Vec<Value>, workers: usize) -> Vec<Value> {
    let reg = Arc::new(labyrinth::workload::registry::Registry::new());
    reg.put("stress_data", data);
    let program = labyrinth::frontend::parse_and_lower(src).unwrap();
    let (graph, _) = labyrinth::compile_with_registry(
        &program,
        &labyrinth::opt::OptConfig::default(),
        &reg,
    )
    .unwrap();
    let out = labyrinth::exec::run(
        &graph,
        &ExecConfig { workers, registry: reg, ..Default::default() },
    )
    .unwrap();
    let mut got = out.collected("out").to_vec();
    got.sort();
    got
}

#[test]
fn concurrent_stress_matches_single_shot() {
    const CLIENTS: usize = 4;
    const JOBS_PER_CLIENT: usize = 6;
    let svc = Arc::new(JobService::new(ServeConfig {
        slots: 2,
        workers: 2,
        ..Default::default()
    }));
    // Expected outputs per (program, seed) pair, computed one-shot.
    let expected: Vec<Vec<Vec<Value>>> = (0..CLIENTS)
        .map(|c| {
            (0..JOBS_PER_CLIENT)
                .map(|j| {
                    let src = PROGRAMS[(c + j) % PROGRAMS.len()];
                    one_shot(src, dataset((c * 100 + j) as i64, 16), 2)
                })
                .collect()
        })
        .collect();

    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let svc = svc.clone();
            let expected = &expected;
            s.spawn(move || {
                for j in 0..JOBS_PER_CLIENT {
                    let src = PROGRAMS[(c + j) % PROGRAMS.len()];
                    let res = svc
                        .run(
                            JobRequest::source(src)
                                .bind("stress_data", dataset((c * 100 + j) as i64, 16)),
                        )
                        .unwrap();
                    let mut got = res.output.collected("out").to_vec();
                    got.sort();
                    assert_eq!(got, expected[c][j], "client {c} job {j} ({src})");
                }
            });
        }
    });

    let m = svc.metrics();
    assert_eq!(m.get("serve.jobs_completed"), (CLIENTS * JOBS_PER_CLIENT) as u64);
    assert_eq!(m.get("serve.jobs_failed"), 0);
    // K distinct programs -> exactly K templates compiled (revisions are
    // not misses); everything else hit the cache.
    assert_eq!(m.get("serve.cache_misses"), PROGRAMS.len() as u64);
    assert!(
        m.get("serve.cache_hits") + m.get("serve.cache_revisions")
            >= (CLIENTS * JOBS_PER_CLIENT - PROGRAMS.len()) as u64
    );
}

#[test]
fn cache_key_separates_opt_configs_and_results_agree() {
    let svc = JobService::new(ServeConfig { slots: 1, adaptive: false, ..Default::default() });
    let src = "v = source(\"stress_data\"); d = 1; s = bag(); while (d <= 3) { s = v.map(|x| x + d); d = d + 1; } collect(s, \"out\");";
    let data = || dataset(7, 12);

    let optimized = svc.run(JobRequest::source(src).bind("stress_data", data())).unwrap();
    assert_eq!(optimized.cache, CacheOutcome::Miss);
    let unoptimized = svc
        .run(
            JobRequest::source(src)
                .bind("stress_data", data())
                .opt(labyrinth::opt::OptConfig::none()),
        )
        .unwrap();
    assert_eq!(
        unoptimized.cache,
        CacheOutcome::Miss,
        "differing opt flags must not share a template"
    );
    assert_eq!(svc.cache().misses(), 2);

    // Same answers from both templates.
    let mut a = optimized.output.collected("out").to_vec();
    let mut b = unoptimized.output.collected("out").to_vec();
    a.sort();
    b.sort();
    assert_eq!(a, b);

    // Resubmitting each hits its own entry.
    let r1 = svc.run(JobRequest::source(src).bind("stress_data", data())).unwrap();
    assert_eq!(r1.cache, CacheOutcome::Hit);
    let r2 = svc
        .run(
            JobRequest::source(src)
                .bind("stress_data", data())
                .opt(labyrinth::opt::OptConfig::none()),
        )
        .unwrap();
    assert_eq!(r2.cache, CacheOutcome::Hit);
    assert_eq!(svc.cache().misses(), 2, "no recompiles on the hit path");
}

#[test]
fn pool_threads_are_reused_across_jobs() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 3,
        adaptive: false,
        ..Default::default()
    });
    const JOBS: usize = 8;
    for i in 0..JOBS {
        let res = svc
            .run(
                JobRequest::source(
                    "v = source(\"stress_data\"); o = v.map(|x| x + 1); collect(o, \"out\");",
                )
                .bind("stress_data", dataset(i as i64, 8)),
            )
            .unwrap();
        assert_eq!(res.output.collected("out").len(), 8);
    }
    // Every job ran as ONE epoch per resident worker — no thread churn
    // (thread-identity stability is asserted in exec::pool's unit tests;
    // the epoch count proves the service reuses one pool).
    assert_eq!(svc.metrics().get("serve.pool_epochs"), (JOBS * 3) as u64);
}

#[test]
fn no_state_bleeds_between_jobs_with_reuse_on() {
    // A loop-invariant hash-join build side is kept across STEPS within
    // a job (§7 reuse). Two tenants submit the same cached template with
    // different build-side data; the second result must reflect ONLY the
    // second tenant's data — a stale hash table from the first epoch
    // would join against tenant A's attributes.
    let src = r#"
        attrs = source("tenant_attrs");
        d = 1;
        while (d <= 3) {
            v = source("tenant_probe").map(|x| pair(x, d));
            j = attrs.join(v);
            t = j.map(|p| fst(snd(p)));
            collect(t, "out");
            d = d + 1;
        }
    "#;
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        reuse_state: true,
        ..Default::default()
    });
    let attrs_a: Vec<Value> = (0..8).map(|k| Value::pair(Value::I64(k), Value::I64(k))).collect();
    let attrs_b: Vec<Value> =
        (0..8).map(|k| Value::pair(Value::I64(k), Value::I64(k + 1000))).collect();
    let probe: Vec<Value> = (0..8).map(Value::I64).collect();

    let run_with = |attrs: &[Value]| -> i64 {
        let res = svc
            .run(
                JobRequest::source(src)
                    .bind("tenant_attrs", attrs.to_vec())
                    .bind("tenant_probe", probe.clone()),
            )
            .unwrap();
        res.output.collected("out").iter().map(|v| v.as_i64()).sum()
    };
    let sum_a = run_with(&attrs_a);
    let sum_b = run_with(&attrs_b);
    // A: payloads 0..8 summed over 3 steps; B: payloads 1000..1008.
    assert_eq!(sum_a, 3 * (0..8).sum::<i64>());
    assert_eq!(sum_b, 3 * (1000..1008).sum::<i64>(), "tenant B saw tenant A's build table");
}

#[test]
fn adaptive_revision_fires_and_stays_correct() {
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        adaptive: true,
        ..Default::default()
    });
    // The filter keeps everything at runtime (observed selectivity 1.0
    // vs the static 0.25 guess), so recorded stats drift from the
    // estimates the first compile used.
    let src = "v = source(\"adapt_data\"); f = v.filter(|x| x >= 0); k = f.map(|x| pair(x % 4, x)); o = k.reduceByKey(|a, b| a + b); collect(o, \"out\");";
    let data = || dataset(0, 64);
    let want = one_shot(src, data(), 2);

    let r1 = svc.run(JobRequest::source(src).bind("adapt_data", data())).unwrap();
    assert_eq!(r1.cache, CacheOutcome::Miss);
    let r2 = svc.run(JobRequest::source(src).bind("adapt_data", data())).unwrap();
    assert_eq!(r2.cache, CacheOutcome::Revised, "observed stats trigger a revision");
    assert_eq!(r2.revision, 1);
    assert_eq!(svc.cache().revisions(), 1);
    for r in [r1, r2] {
        let mut got = r.output.collected("out").to_vec();
        got.sort();
        assert_eq!(got, want, "revisions preserve semantics");
    }
    // The revision converges: stats from the revised plan match what it
    // was optimized with, so the third submission is a plain hit.
    let r3 = svc.run(JobRequest::source(src).bind("adapt_data", data())).unwrap();
    assert_eq!(r3.cache, CacheOutcome::Hit, "no oscillating re-optimization");
}

#[test]
fn barrier_mode_service_matches_pipelined() {
    let src = "v = source(\"stress_data\"); d = 1; s = bag(); while (d <= 4) { s = v.map(|x| x * d); d = d + 1; } collect(s, \"out\");";
    let pipelined = JobService::new(ServeConfig { slots: 1, ..Default::default() });
    let barrier =
        JobService::new(ServeConfig { slots: 1, mode: ExecMode::Barrier, ..Default::default() });
    let a = pipelined
        .run(JobRequest::source(src).bind("stress_data", dataset(1, 10)))
        .unwrap();
    let b = barrier
        .run(JobRequest::source(src).bind("stress_data", dataset(1, 10)))
        .unwrap();
    let mut av = a.output.collected("out").to_vec();
    let mut bv = b.output.collected("out").to_vec();
    av.sort();
    bv.sort();
    assert_eq!(av, bv);
}

#[test]
fn canceled_queued_job_never_runs() {
    // One slot busy with a slow job; a queued job canceled before the
    // lane reaches it must fail with a cancellation error.
    let svc = JobService::new(ServeConfig { slots: 1, workers: 2, ..Default::default() });
    let slow = svc
        .submit(JobRequest::source(
            "d = 1; while (d <= 3000) { d = d + 1; } collect(bag(1), \"x\");",
        ))
        .unwrap();
    let victim = svc.submit(JobRequest::source("collect(bag(2), \"y\");")).unwrap();
    victim.cancel();
    let err = victim.wait().unwrap_err();
    assert!(err.to_string().contains("canceled"), "{err}");
    assert!(slow.wait().is_ok());
    assert_eq!(svc.metrics().get("serve.jobs_canceled"), 1);
}

#[test]
fn deadline_bounds_a_running_job() {
    let svc = JobService::new(ServeConfig { slots: 1, workers: 2, ..Default::default() });
    // A genuinely long job (tens of thousands of coordination steps)
    // with a tight running deadline must abort rather than run to
    // completion — and the lane must stay usable afterwards.
    let err = svc
        .run(
            JobRequest::source(
                "d = 1; while (d <= 2000000) { d = d + 1; } collect(bag(1), \"x\");",
            )
            .deadline(Duration::from_millis(150)),
        )
        .unwrap_err();
    assert!(err.to_string().contains("deadline"), "{err}");
    let ok = svc.run(JobRequest::source("collect(bag(3), \"z\");")).unwrap();
    assert_eq!(ok.output.collected("z"), &[Value::I64(3)]);
}
