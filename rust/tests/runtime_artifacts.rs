//! End-to-end PJRT tests: the Rust runtime executes the AOT artifacts
//! produced by `make artifacts` and the numerics match the pure-Rust
//! references. Skipped (with a loud message) when artifacts are missing.

use labyrinth::bag::Bag;
use labyrinth::ops::{run_once, xla::XlaCallT};
use labyrinth::runtime::XlaCallSpec;
use labyrinth::value::Value;

const PAGERANK_N: usize = 512;
const HIST_CAPACITY: usize = 4096;
const HIST_BINS: usize = 2048;
const INCR_CAPACITY: usize = 256;

fn artifacts_available() -> bool {
    let ok = labyrinth::runtime::XlaService::global().available("incr");
    if !ok {
        eprintln!("SKIP: artifacts/ not built — run `make artifacts`");
    }
    ok
}

#[test]
fn incr_artifact_increments() {
    if !artifacts_available() {
        return;
    }
    let mut t = XlaCallT::new(XlaCallSpec::incr(INCR_CAPACITY));
    let input: Vec<Value> = (0..300).map(|i| Value::F64(i as f64)).collect();
    let out = run_once(&mut t, &[&input]);
    assert_eq!(out.len(), 300, "chunking must preserve count");
    let mut got: Vec<f64> = out.iter().map(|v| v.as_f64()).collect();
    got.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (i, g) in got.iter().enumerate() {
        assert!((g - (i as f64 + 1.0)).abs() < 1e-5, "{i}: {g}");
    }
}

#[test]
fn histogram_artifact_counts() {
    if !artifacts_available() {
        return;
    }
    let mut t = XlaCallT::new(XlaCallSpec::histogram(HIST_CAPACITY, HIST_BINS));
    // 5000 ids (forces chunking) over 3 bins with known counts.
    let mut input = Vec::new();
    for i in 0..5000u64 {
        input.push(Value::I64((i % 3) as i64));
    }
    let out = run_once(&mut t, &[&input]);
    let mut counts = std::collections::BTreeMap::new();
    for v in &out {
        counts.insert(v.key().as_i64(), v.val().as_i64());
    }
    assert_eq!(counts.get(&0), Some(&1667));
    assert_eq!(counts.get(&1), Some(&1667));
    assert_eq!(counts.get(&2), Some(&1666));
    assert_eq!(counts.len(), 3);
}

#[test]
fn pagerank_artifact_matches_reference() {
    if !artifacts_available() {
        return;
    }
    let n = PAGERANK_N;
    // Ring + chords graph.
    let mut edges = Vec::new();
    for i in 0..n {
        edges.push((i, (i + 1) % n));
        if i % 3 == 0 {
            edges.push((i, (i + 7) % n));
        }
    }
    let edge_bag: Vec<Value> = edges
        .iter()
        .map(|&(s, d)| Value::pair(Value::I64(s as i64), Value::I64(d as i64)))
        .collect();
    let init: Vec<Value> = (0..n)
        .map(|p| Value::pair(Value::I64(p as i64), Value::F64(1.0 / n as f64)))
        .collect();

    let mut t = XlaCallT::new(XlaCallSpec::pagerank_step(n));
    // Step 1: feed edges (build side) + ranks.
    let mut ranks = run_once(&mut t, &[&edge_bag, &init]);
    // Steps 2..10: reuse the cached matrix (runtime contract: input 0 not
    // re-fed when unchanged).
    for _ in 1..10 {
        let mut out = labyrinth::ops::VecCollector::default();
        use labyrinth::ops::Transformation;
        t.open_out_bag();
        for v in &ranks {
            t.push_in_element(1, v, &mut out);
        }
        t.close_in_bag(1, &mut out);
        t.close_out_bag(&mut out);
        ranks = out.items;
    }

    let want = labyrinth::workload::pagerank_reference(&edges, n, 10);
    let mut got = vec![0.0; n];
    for v in &ranks {
        got[v.key().as_i64() as usize] = v.val().as_f64();
    }
    // f32 artifact vs f64 reference, 10 steps: tolerate small drift.
    for i in 0..n {
        assert!(
            (got[i] - want[i]).abs() < 1e-4,
            "rank[{i}]: got {} want {}",
            got[i],
            want[i]
        );
    }
    let sum: f64 = got.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "mass {sum}");
}

#[test]
fn pagerank_inside_labyrinth_dataflow() {
    if !artifacts_available() {
        return;
    }
    // Drive the artifact from inside a compiled Labyrinth loop: the edge
    // input is loop-invariant (tensorized once, §7), the rank bag flows
    // through a Φ.
    use labyrinth::prelude::*;
    let n = PAGERANK_N;
    let mut edges = Vec::new();
    for i in 0..n {
        edges.push((i, (i + 1) % n));
        edges.push((i, (i * 2 + 1) % n));
    }
    let edge_vals: Vec<Value> = edges
        .iter()
        .map(|&(s, d)| Value::pair(Value::I64(s as i64), Value::I64(d as i64)))
        .collect();
    labyrinth::workload::registry::global().put("pr_edges", edge_vals);
    let init: Vec<Value> = (0..n)
        .map(|p| Value::pair(Value::I64(p as i64), Value::F64(1.0 / n as f64)))
        .collect();

    let mut b = ProgramBuilder::new();
    let edges_bag = b.named_source("pr_edges");
    let init_bag = b.bag_lit(init);
    let ranks = b.declare_bag("ranks", init_bag);
    let i0 = b.scalar_i64(0);
    let i = b.declare_scalar("i", i0);
    b.while_(
        |b| b.scalar_lt_i64(i, 5),
        |b| {
            let next = b.xla_call(vec![edges_bag, ranks], XlaCallSpec::pagerank_step(n));
            b.assign_bag(ranks, next);
            let i2 = b.scalar_add_i64(i, 1);
            b.assign_scalar(i, i2);
        },
    );
    b.collect(ranks, "ranks");
    let program = b.finish();
    let graph = labyrinth::compile(&program).unwrap();
    let out = run(&graph, &ExecConfig { workers: 2, ..Default::default() }).unwrap();

    let want = labyrinth::workload::pagerank_reference(&edges, n, 5);
    let got_bag = out.collected("ranks");
    assert_eq!(got_bag.len(), n);
    let mut got = vec![0.0; n];
    for v in got_bag {
        got[v.key().as_i64() as usize] = v.val().as_f64();
    }
    for idx in 0..n {
        assert!(
            (got[idx] - want[idx]).abs() < 1e-4,
            "rank[{idx}]: got {} want {}",
            got[idx],
            want[idx]
        );
    }
}
