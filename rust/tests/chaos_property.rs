//! Property-based chaos: the seeded random-program family runs under
//! seeded random fault plans and randomized checkpoint cadences, and
//! must still agree with the fault-free single-threaded oracle. This
//! composes the repo's two strongest levers — differential testing
//! against the §6.3.1 spec executor and deterministic fault injection —
//! into one harness: any divergence reproduces from `(seed)` alone.

use labyrinth::baselines::single_thread;
use labyrinth::exec::{run, ExecConfig, FaultPlan};
use labyrinth::frontend::parse_and_lower;
use labyrinth::util::quickcheck::{
    batch_for_seed, checkpoint_for_seed, random_laby_program as random_program,
    RANDOM_PROGRAM_LABELS,
};
use labyrinth::value::Value;
use std::sync::Arc;
use std::time::Duration;

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

#[test]
fn random_programs_survive_random_faults() {
    for seed in 0..20u64 {
        let src = random_program(seed);
        let program = parse_and_lower(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: parse/lower failed: {e}\n{src}"));
        let oracle = single_thread::run(&program, &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: oracle failed: {e}\n{src}"));
        let graph = labyrinth::compile(&program)
            .unwrap_or_else(|e| panic!("seed {seed}: compile failed: {e}\n{src}"));

        // Batch size, checkpoint cadence, and the fault schedule all
        // derive from the seed — the sweep covers the grid across seeds
        // without multiplying runtime.
        let batch = batch_for_seed(seed);
        let checkpoint_every = checkpoint_for_seed(seed);
        let cfg = ExecConfig {
            workers: 2,
            batch,
            checkpoint_every,
            faults: Some(Arc::new(FaultPlan::seeded(seed))),
            stall_timeout: Duration::from_secs(30),
            ..Default::default()
        };
        let out = run(&graph, &cfg).unwrap_or_else(|e| {
            panic!("seed {seed} batch={batch} ckpt={checkpoint_every:?}: {e}\n{src}")
        });
        for label in RANDOM_PROGRAM_LABELS {
            assert_eq!(
                multiset(out.collected(label).to_vec()),
                multiset(oracle.collected(label).to_vec()),
                "seed {seed} label {label} batch={batch} ckpt={checkpoint_every:?}\n{src}"
            );
        }
        // Recovery bookkeeping stays coherent whenever a resume happened.
        let recovered = out.metrics.get("exec.supersteps_recovered");
        if recovered > 0 {
            assert_eq!(
                recovered + out.metrics.get("exec.supersteps_replayed"),
                out.path_len as u64,
                "seed {seed}: recovered + replayed must cover the path\n{src}"
            );
            assert!(
                out.metrics.get("exec.epoch_retries") > 0,
                "seed {seed}: resume without a retry?\n{src}"
            );
        }
    }
}

#[test]
fn explicit_panics_under_random_programs_and_cadences() {
    // Deterministic single-panic schedules (not seeded draws) across the
    // program family: panic worker 1 at superstep 2, every cadence.
    for seed in 40..52u64 {
        let src = random_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let graph = labyrinth::compile(&program).unwrap();
        for &checkpoint_every in &[Some(1u32), Some(3), None] {
            let cfg = ExecConfig {
                workers: 2,
                checkpoint_every,
                faults: Some(Arc::new(FaultPlan::new().panic_at(1, 2))),
                stall_timeout: Duration::from_secs(30),
                ..Default::default()
            };
            let out = run(&graph, &cfg).unwrap_or_else(|e| {
                panic!("seed {seed} ckpt={checkpoint_every:?}: {e}\n{src}")
            });
            for label in RANDOM_PROGRAM_LABELS {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(oracle.collected(label).to_vec()),
                    "seed {seed} label {label} ckpt={checkpoint_every:?}\n{src}"
                );
            }
            assert_eq!(out.metrics.get("exec.epoch_retries"), 1, "seed {seed}");
            assert_eq!(out.metrics.get("exec.faults_injected"), 1, "seed {seed}");
        }
    }
}
