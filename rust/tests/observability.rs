//! Integration tests for the `obs::` tracing layer: the disabled path
//! records nothing, a traced loop epoch yields a well-formed span
//! hierarchy (supersteps and operator work nested inside the epoch),
//! the Chrome-trace export is structurally valid, and the `serve::`
//! lifecycle spans + latency histograms land.

use labyrinth::exec::{run, ExecConfig};
use labyrinth::obs::{chrome, SpanKind, Tracer};
use labyrinth::serve::{JobRequest, JobService, ServeConfig};
use labyrinth::value::Value;
use labyrinth::workload::registry::Registry;
use std::sync::Arc;

/// A fig-6-style counter loop: three iterations of a map over a named
/// source, final iteration's bag collected.
const LOOP_SRC: &str = r#"
    v = source("obs_data");
    d = 1;
    s = bag();
    while (d <= 3) {
        s = v.map(|x| x + d);
        d = d + 1;
    }
    collect(s, "out");
"#;

fn compile_loop(reg: &Arc<Registry>) -> labyrinth::dataflow::DataflowGraph {
    reg.put("obs_data", (0..64i64).map(Value::I64).collect());
    let program = labyrinth::frontend::parse_and_lower(LOOP_SRC).unwrap();
    let (graph, _) = labyrinth::compile_with_registry(
        &program,
        &labyrinth::opt::OptConfig::default(),
        reg,
    )
    .unwrap();
    graph
}

fn traced_run(workers: usize) -> (labyrinth::obs::Trace, labyrinth::exec::RunOutput) {
    let reg = Arc::new(Registry::new());
    let graph = compile_loop(&reg);
    let tracer = Arc::new(Tracer::new(true));
    let cfg = ExecConfig {
        workers,
        registry: reg,
        trace: Some(tracer.clone()),
        ..Default::default()
    };
    let out = run(&graph, &cfg).unwrap();
    assert!(!out.collected("out").is_empty());
    (tracer.take(), out)
}

#[test]
fn disabled_tracer_records_no_events_and_no_self_time() {
    let reg = Arc::new(Registry::new());
    let graph = compile_loop(&reg);
    let tracer = Arc::new(Tracer::new(false));
    let cfg = ExecConfig {
        workers: 2,
        registry: reg,
        trace: Some(tracer.clone()),
        ..Default::default()
    };
    let out = run(&graph, &cfg).unwrap();
    assert!(!out.collected("out").is_empty());
    let trace = tracer.take();
    assert!(
        trace.events.is_empty(),
        "disabled tracer must record zero events, got {}",
        trace.events.len()
    );
    assert_eq!(trace.dropped, 0);
    assert!(
        out.node_rows.iter().all(|r| r.self_time_ns == 0),
        "self-time stays zero when tracing is off"
    );
}

#[test]
fn traced_loop_yields_wellformed_span_hierarchy() {
    // Single worker: every operator span runs on one thread, so their
    // durations are non-overlapping and must sum to <= the epoch wall.
    let (trace, out) = traced_run(1);
    assert_eq!(trace.dropped, 0);

    let epochs = trace.spans(|k| *k == SpanKind::Epoch);
    assert_eq!(epochs.len(), 1, "one run = one epoch span");
    let epoch = epochs[0];
    let e_end = epoch.ts + epoch.dur;

    // Supersteps: one per appended path position, nested in the epoch.
    let steps = trace.spans(|k| matches!(k, SpanKind::Superstep { .. }));
    assert!(
        steps.len() >= out.path_len.min(3),
        "expected superstep spans for a {}-step path, got {}",
        out.path_len,
        steps.len()
    );
    for s in &steps {
        assert!(s.ts >= epoch.ts && s.ts + s.dur <= e_end, "superstep within epoch");
    }
    // Positions cover a strictly increasing path prefix.
    let mut last_pos = 0u32;
    for s in &steps {
        if let SpanKind::Superstep { pos, blocks, .. } = s.kind {
            assert!(pos > last_pos || last_pos == 0, "monotonic path positions");
            assert!(blocks >= 1);
            last_pos = pos;
        }
    }

    // Operator spans: present, inside the epoch, and (w=1) summing to
    // no more than the epoch wall time.
    let work = trace.spans(|k| {
        matches!(
            k,
            SpanKind::NodeBatch { .. } | SpanKind::NodeClose { .. } | SpanKind::Generate { .. }
        )
    });
    assert!(!work.is_empty(), "a traced run records operator spans");
    let mut total = 0u64;
    for s in &work {
        assert!(s.ts >= epoch.ts && s.ts + s.dur <= e_end, "operator span within epoch");
        total += s.dur;
    }
    assert!(
        total <= epoch.dur,
        "w=1 operator self-time ({total}ns) cannot exceed the epoch wall ({}ns)",
        epoch.dur
    );

    // Dispatch and drain bracket the epoch on the driver lane.
    assert_eq!(trace.spans(|k| *k == SpanKind::Dispatch).len(), 1);
    assert_eq!(trace.spans(|k| *k == SpanKind::Drain).len(), 1);

    // Measured self-time feeds back into RunOutput.
    let traced_total: u64 = out.node_rows.iter().map(|r| r.self_time_ns).sum();
    assert!(traced_total > 0, "traced runs report per-node self-time");
    assert_eq!(traced_total, total, "node_rows self-time mirrors the span sum");
}

#[test]
fn chrome_export_is_balanced_and_loadable() {
    let reg = Arc::new(Registry::new());
    let graph = compile_loop(&reg);
    let tracer = Arc::new(Tracer::new(true));
    let cfg = ExecConfig {
        workers: 2,
        registry: reg,
        trace: Some(tracer.clone()),
        ..Default::default()
    };
    let out = run(&graph, &cfg).unwrap();
    let trace = tracer.take();

    let events = chrome::chrome_events(&trace, Some(&graph));
    chrome::validate(&events).expect("balanced B/E pairs, monotonic timestamps");
    let json = chrome::render(&events);
    assert!(json.starts_with("{"));
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"displayTimeUnit\""));

    // The human breakdown renders the same trace without panicking and
    // names the epoch + at least one operator.
    let report = labyrinth::obs::report::render_breakdown(&trace, &graph, &out);
    assert!(report.contains("epoch"), "breakdown mentions the epoch: {report}");
    assert!(report.contains("superstep"), "breakdown lists supersteps: {report}");
}

#[test]
fn serve_trace_records_job_lifecycle_spans() {
    let tracer = Arc::new(Tracer::new(true));
    let svc = JobService::new(ServeConfig {
        slots: 1,
        workers: 2,
        trace: Some(tracer.clone()),
        ..Default::default()
    });
    for _ in 0..2 {
        svc.run(JobRequest::source("collect(bag(7), \"x\");")).unwrap();
    }
    let trace = tracer.take();
    let queues = trace.spans(|k| matches!(k, SpanKind::Queue { .. }));
    let runs = trace.spans(|k| matches!(k, SpanKind::JobRun { .. }));
    let requests = trace.spans(|k| matches!(k, SpanKind::Request { .. }));
    assert_eq!(queues.len(), 2, "one queue span per job");
    assert_eq!(runs.len(), 2, "one engine-epoch span per job");
    assert_eq!(requests.len(), 2, "one request span per job");
    // A request encloses its job's engine epoch.
    for (rq, jr) in requests.iter().zip(runs.iter()) {
        assert!(rq.ts <= jr.ts && rq.ts + rq.dur >= jr.ts + jr.dur);
    }
    // Exactly one compile span: the second job is a template-cache hit.
    let compiles = trace.spans(|k| matches!(k, SpanKind::Compile { .. }));
    assert_eq!(compiles.len(), 1, "cache hit skips the compile span");
}

#[test]
fn serve_histograms_report_tail_latencies() {
    let svc = JobService::new(ServeConfig { slots: 1, workers: 2, ..Default::default() });
    const JOBS: usize = 5;
    for _ in 0..JOBS {
        svc.run(JobRequest::source("collect(bag(1), \"x\");")).unwrap();
    }
    let m = svc.metrics();
    for key in ["serve.queue_wait", "serve.job_time", "serve.request_time"] {
        let s = m.time_stats(key).unwrap_or_else(|| panic!("{key} histogram missing"));
        assert_eq!(s.count, JOBS as u64, "{key} records every job");
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99, "{key} quantiles are ordered");
        // Log buckets estimate within 2x: p99 <= 2 * max <= 2 * total.
        assert!(s.p99 <= s.total * 2, "{key} p99 within the bucket-resolution bound");
    }
    let report = svc.report();
    assert!(report.contains("p99"), "service report shows tail latencies: {report}");
    assert!(report.contains("serve.request_time"), "report names the histogram");
}
