//! The two coordination challenges of §6.2, as executable programs
//! (Listing 3a and 3b of the paper), validated against the single-threaded
//! specification executor.

use labyrinth::baselines::single_thread;
use labyrinth::exec::{run, ExecConfig, ExecMode};
use labyrinth::frontend::parse_and_lower;
use labyrinth::value::Value;

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

fn check_against_oracle(src: &str, labels: &[&str], workers: &[usize]) {
    let program = parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let graph = labyrinth::compile(&program).unwrap();
    for &w in workers {
        for mode in [ExecMode::Pipelined, ExecMode::Barrier] {
            let out = run(
                &graph,
                &ExecConfig { workers: w, mode, ..Default::default() },
            )
            .unwrap_or_else(|e| panic!("workers={w} mode={mode:?}: {e}"));
            for label in labels {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(oracle.collected(label).to_vec()),
                    "label '{label}' mismatch at workers={w} mode={mode:?}"
                );
            }
        }
    }
}

/// Listing 3a: `z = f(x, y)` where `x` is produced once per OUTER step and
/// `y` once per INNER step — input-bag matching is not one-to-one; the
/// runtime must reuse x's bag for every inner step (Challenge 1).
#[test]
fn listing_3a_nested_loop_bag_matching() {
    let src = r#"
        i = 0;
        while (i < 3) {
            x = bag(10, 20).map(|v| v + i * 100);
            j = 0;
            while (j < 2) {
                y = bag(1, 2).map(|v| v + j * 7);
                z = x.cross(y);
                collect(z, "z");
                j = j + 1;
            }
            i = i + 1;
        }
    "#;
    check_against_oracle(src, &["z"], &[1, 2, 4]);
}

/// Listing 3a with a keyed binary operator: x joins y across loop depths.
#[test]
fn listing_3a_with_join() {
    let src = r#"
        i = 0;
        while (i < 3) {
            x = bag(1, 2, 3).map(|v| pair(v, v * 10 + i));
            j = 0;
            while (j < 2) {
                y = bag(2, 3, 4).map(|v| pair(v, j));
                z = y.join(x).map(|p| pair(fst(p), fst(snd(p)) + snd(snd(p))));
                collect(z, "z");
                j = j + 1;
            }
            i = i + 1;
        }
    "#;
    check_against_oracle(src, &["z"], &[1, 3]);
}

/// Listing 3b: Φs after an if-else inside a loop. First-come-first-served
/// input selection would pair x-bags with wrong y-bags across steps
/// (path ABDACD); the execution-path rule must keep them aligned
/// (Challenge 2).
#[test]
fn listing_3b_phi_alignment_across_branches() {
    let src = r#"
        i = 0;
        acc = bag();
        while (i < 6) {
            x = bag(0);
            y = bag(0);
            if (i % 2 == 0) {
                x = bag(1).map(|v| v + i * 10);
                y = bag(2).map(|v| v + i * 10);
            } else {
                x = bag(3).map(|v| v + i * 1000);
                y = bag(4).map(|v| v + i * 1000);
            }
            z = x.union(y);
            collect(z, "z");
            i = i + 1;
        }
    "#;
    check_against_oracle(src, &["z"], &[1, 2, 4]);
}

/// Listing 3b variant where the branches are data-dependent (the decision
/// is computed from bag data, so the path truly can't be predicted).
#[test]
fn listing_3b_data_dependent_branching() {
    let src = r#"
        i = 0;
        carry = bag(5, 6, 7);
        while (i < 5) {
            n = carry.reduce(|a, b| a + b);
            if (n % 2 == 0) {
                carry = carry.map(|v| v + 1);
            } else {
                carry = carry.map(|v| v * 2);
            }
            collect(carry, "trace");
            i = i + 1;
        }
    "#;
    check_against_oracle(src, &["trace"], &[1, 3]);
}

/// The invariant-bag case of Challenge 1 (§3.2.2): the consumer keeps the
/// build-side bag across MANY output bags while the path loops.
#[test]
fn invariant_bag_reused_across_many_steps() {
    let src = r#"
        lookup = bag(0, 1, 2, 3, 4).map(|v| pair(v, v * 111));
        i = 0;
        while (i < 8) {
            probe = bag(0, 1, 2, 3, 4).map(|v| pair((v + i) % 5, i));
            z = probe.join(lookup).map(|p| fst(snd(p)));
            collect(z, "z");
            i = i + 1;
        }
    "#;
    check_against_oracle(src, &["z"], &[1, 2, 4]);
}
