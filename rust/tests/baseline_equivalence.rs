//! Differential testing: a seeded family of random imperative programs is
//! executed by every executor; all outputs must match the single-threaded
//! specification (§6.3.1 uses the non-parallel execution as the spec).
//! This is the strongest whole-system property we check.

use labyrinth::baselines::{separate_jobs, single_thread};
use labyrinth::exec::{run, ExecConfig, ExecMode};
use labyrinth::frontend::parse_and_lower;
use labyrinth::util::rng::Rng;
use labyrinth::value::Value;

/// Generate a random-but-well-formed LabyLang program from a seed. The
/// family covers: loops with data-dependent trip counts, if/else over
/// loop parity and bag aggregates, loop-carried bags, invariant joins,
/// keyed aggregation, and scalar capture desugaring.
fn random_program(seed: u64) -> String {
    let mut r = Rng::new(seed);
    let steps = 2 + r.gen_range(5); // 2..=6
    let lit: Vec<String> = (0..(3 + r.gen_range(5)))
        .map(|_| r.gen_range(50).to_string())
        .collect();
    let lit = lit.join(", ");
    let branch_kind = r.gen_range(3);
    let use_join = r.gen_bool(0.5);
    let use_carry = r.gen_bool(0.7);
    let mulk = 1 + r.gen_range(4);

    let mut body = String::new();
    body.push_str(&format!(
        "    cur = bag({lit}).map(|v| v + i * {mulk});\n"
    ));
    if use_join {
        body.push_str(
            "    kv = cur.map(|v| pair(v % 7, v));\n     j = kv.join(lookup).map(|p| fst(snd(p)) + snd(snd(p)));\n     collect(j, \"joined\");\n",
        );
    }
    match branch_kind {
        0 => body.push_str(
            "    if (i % 2 == 0) { acc = acc.union(cur); } else { acc = cur; }\n",
        ),
        1 => body.push_str(
            "    n = cur.reduce(|a, b| a + b);\n    if (n % 3 == 0) { acc = cur.map(|v| v + 1); }\n",
        ),
        _ => body.push_str("    acc = acc.union(cur.filter(|v| v % 2 == 0));\n"),
    }
    // Unstructured control flow: early exits and skips.
    if r.gen_bool(0.3) {
        body.push_str("    if (i == 4) { i = i + 1; continue; }\n");
    }
    if r.gen_bool(0.3) {
        let cut = 2 + r.gen_range(3);
        body.push_str(&format!("    if (i >= {cut}) {{ break; }}\n"));
    }
    if use_carry {
        body.push_str(
            "    counts = cur.map(|v| pair(v % 5, 1)).reduceByKey(|a, b| a + b);\n     collect(counts, \"counts\");\n",
        );
    }

    format!(
        r#"
lookup = bag(0, 1, 2, 3, 4, 5, 6).map(|v| pair(v, v * 100));
acc = bag();
i = 0;
while (i < {steps}) {{
{body}    i = i + 1;
}}
collect(acc, "acc");
"#
    )
}

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

#[test]
fn random_programs_agree_across_all_executors() {
    let labels = ["acc", "joined", "counts"];
    for seed in 0..24u64 {
        let src = random_program(seed);
        let program = parse_and_lower(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: parse/lower failed: {e}\n{src}"));
        let oracle = single_thread::run(&program, &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: oracle failed: {e}\n{src}"));

        // Labyrinth: multiple worker counts + both modes.
        let graph = labyrinth::compile(&program)
            .unwrap_or_else(|e| panic!("seed {seed}: compile failed: {e}\n{src}"));
        for workers in [1usize, 3] {
            for mode in [ExecMode::Pipelined, ExecMode::Barrier] {
                let out = run(
                    &graph,
                    &ExecConfig { workers, mode, ..Default::default() },
                )
                .unwrap_or_else(|e| panic!("seed {seed} w={workers} {mode:?}: {e}\n{src}"));
                for label in &labels {
                    assert_eq!(
                        multiset(out.collected(label).to_vec()),
                        multiset(oracle.collected(label).to_vec()),
                        "seed {seed} label {label} workers {workers} {mode:?}\n{src}"
                    );
                }
            }
        }

        // Separate-jobs baselines.
        for cfg in [
            separate_jobs::SeparateJobsConfig::spark(2),
            separate_jobs::SeparateJobsConfig::flink(2),
        ] {
            let out = separate_jobs::run(&program, &cfg)
                .unwrap_or_else(|e| panic!("seed {seed} separate-jobs: {e}\n{src}"));
            for label in &labels {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(oracle.collected(label).to_vec()),
                    "seed {seed} label {label} separate-jobs\n{src}"
                );
            }
        }
    }
}

#[test]
fn reuse_toggle_never_changes_results() {
    for seed in 100..112u64 {
        let src = random_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let graph = labyrinth::compile(&program).unwrap();
        let a = run(&graph, &ExecConfig { workers: 2, ..Default::default() }).unwrap();
        let b = run(
            &graph,
            &ExecConfig { workers: 2, reuse_state: false, ..Default::default() },
        )
        .unwrap();
        for label in ["acc", "joined", "counts"] {
            assert_eq!(
                multiset(a.collected(label).to_vec()),
                multiset(b.collected(label).to_vec()),
                "seed {seed} label {label}\n{src}"
            );
        }
    }
}
