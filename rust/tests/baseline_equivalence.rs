//! Differential testing: a seeded family of random imperative programs is
//! executed by every executor; all outputs must match the single-threaded
//! specification (§6.3.1 uses the non-parallel execution as the spec).
//! This is the strongest whole-system property we check.

use labyrinth::baselines::{separate_jobs, single_thread};
use labyrinth::exec::{run, ExecConfig, ExecMode};
use labyrinth::frontend::parse_and_lower;
use labyrinth::util::quickcheck::{
    batch_for_seed, random_laby_program as random_program, RANDOM_PROGRAM_LABELS,
};
use labyrinth::value::Value;

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

#[test]
fn random_programs_agree_across_all_executors() {
    let labels = RANDOM_PROGRAM_LABELS;
    for seed in 0..24u64 {
        let src = random_program(seed);
        let program = parse_and_lower(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: parse/lower failed: {e}\n{src}"));
        let oracle = single_thread::run(&program, &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: oracle failed: {e}\n{src}"));

        // Labyrinth: multiple worker counts + both modes.
        let graph = labyrinth::compile(&program)
            .unwrap_or_else(|e| panic!("seed {seed}: compile failed: {e}\n{src}"));
        // Batch size randomized per seed (batch-boundary coverage).
        let batch = batch_for_seed(seed);
        for workers in [1usize, 3] {
            for mode in [ExecMode::Pipelined, ExecMode::Barrier] {
                let out = run(
                    &graph,
                    &ExecConfig { workers, mode, batch, ..Default::default() },
                )
                .unwrap_or_else(|e| {
                    panic!("seed {seed} w={workers} {mode:?} batch={batch}: {e}\n{src}")
                });
                for label in labels {
                    assert_eq!(
                        multiset(out.collected(label).to_vec()),
                        multiset(oracle.collected(label).to_vec()),
                        "seed {seed} label {label} workers {workers} {mode:?}\n{src}"
                    );
                }
            }
        }

        // Separate-jobs baselines.
        for cfg in [
            separate_jobs::SeparateJobsConfig::spark(2),
            separate_jobs::SeparateJobsConfig::flink(2),
        ] {
            let out = separate_jobs::run(&program, &cfg)
                .unwrap_or_else(|e| panic!("seed {seed} separate-jobs: {e}\n{src}"));
            for label in labels {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(oracle.collected(label).to_vec()),
                    "seed {seed} label {label} separate-jobs\n{src}"
                );
            }
        }
    }
}

#[test]
fn reuse_toggle_never_changes_results() {
    for seed in 100..112u64 {
        let src = random_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let graph = labyrinth::compile(&program).unwrap();
        let a = run(&graph, &ExecConfig { workers: 2, ..Default::default() }).unwrap();
        let b = run(
            &graph,
            &ExecConfig { workers: 2, reuse_state: false, ..Default::default() },
        )
        .unwrap();
        for label in RANDOM_PROGRAM_LABELS {
            assert_eq!(
                multiset(a.collected(label).to_vec()),
                multiset(b.collected(label).to_vec()),
                "seed {seed} label {label}\n{src}"
            );
        }
    }
}
