//! Differential testing for the typed columnar data plane (`opt::types`
//! + `bag::column` + the typed kernels): a seeded family of typed-source
//! programs runs with the columnar gate forced ON, forced OFF, and
//! against the single-threaded oracle — outputs must agree as multisets
//! at every channel batch size. A non-vacuousness floor checks that at
//! least half the generated programs actually infer concrete types on
//! every hot-chain edge (otherwise the sweep would pass by running the
//! dynamic path everywhere). A chaos leg injects mid-loop worker panics
//! with columnar on and checks that checkpointed state (which may have
//! been built by typed kernels) round-trips through `InstanceSnapshot`.

use labyrinth::baselines::single_thread;
use labyrinth::dataflow::DataflowGraph;
use labyrinth::exec::{run, ExecConfig, FaultPlan};
use labyrinth::frontend::{parse_and_lower, Rhs};
use labyrinth::opt::{ColumnarGate, OptConfig};
use labyrinth::util::quickcheck::{
    checkpoint_for_seed, random_typed_program, BATCH_SIZES, TYPED_PROGRAM_LABELS,
};
use labyrinth::value::{ElemType, Value};
use std::sync::Arc;
use std::time::Duration;

fn multiset(mut v: Vec<Value>) -> Vec<Value> {
    v.sort();
    v
}

fn gate_cfg(gate: ColumnarGate) -> OptConfig {
    OptConfig { columnar: gate, ..Default::default() }
}

/// Every hot-chain edge (input of a map / filter / fused / reduceByKey /
/// join node) carries a concrete inferred type. `false` also when the
/// graph has no hot nodes at all — that program proves nothing.
fn hot_edges_all_typed(g: &DataflowGraph) -> bool {
    let mut any = false;
    for n in &g.nodes {
        if !matches!(
            n.op,
            Rhs::Map { .. }
                | Rhs::Filter { .. }
                | Rhs::Fused { .. }
                | Rhs::ReduceByKey { .. }
                | Rhs::Join { .. }
        ) {
            continue;
        }
        for inp in &n.inputs {
            any = true;
            if g.elem_type(inp.src) == ElemType::Dyn {
                return false;
            }
        }
    }
    any
}

#[test]
fn random_typed_programs_agree_on_off_and_with_oracle() {
    let total = 24u64;
    let mut fully_typed = 0usize;
    for seed in 0..total {
        let (src, clean) = random_typed_program(seed);
        let program = parse_and_lower(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: parse/lower failed: {e}\n{src}"));
        let oracle = single_thread::run(&program, &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: oracle failed: {e}\n{src}"));
        let (g_on, rep) = labyrinth::compile_with(&program, &gate_cfg(ColumnarGate::Always))
            .unwrap_or_else(|e| panic!("seed {seed}: columnar-on compile failed: {e}\n{src}"));
        let (g_off, _) = labyrinth::compile_with(&program, &gate_cfg(ColumnarGate::Never))
            .unwrap_or_else(|e| panic!("seed {seed}: columnar-off compile failed: {e}\n{src}"));

        let typed = hot_edges_all_typed(&g_on);
        fully_typed += usize::from(typed);
        if typed {
            assert!(
                rep.typed_edges > 0,
                "seed {seed}: hot chains typed but explain reports 0 typed edges\n{src}"
            );
        }

        for &batch in BATCH_SIZES {
            for (graph, mode) in [(&g_on, "columnar-on"), (&g_off, "columnar-off")] {
                let out = run(
                    graph,
                    &ExecConfig { workers: 2, batch, ..Default::default() },
                )
                .unwrap_or_else(|e| panic!("seed {seed} {mode} batch={batch}: {e}\n{src}"));
                for label in TYPED_PROGRAM_LABELS {
                    assert_eq!(
                        multiset(out.collected(label).to_vec()),
                        multiset(oracle.collected(label).to_vec()),
                        "seed {seed} label {label} {mode} batch={batch} (clean={clean}, typed={typed})\n{src}",
                    );
                }
            }
        }
    }
    // Non-vacuousness floor: the sweep must exercise the typed kernels on
    // real plans, not degrade to the dynamic path everywhere. The
    // generator keeps ~3/4 of programs free of deliberate
    // inference-defeaters, so at least half must type fully.
    assert!(
        fully_typed as u64 * 2 >= total,
        "only {fully_typed}/{total} programs had every hot-chain edge typed"
    );
}

#[test]
fn masked_filter_chains_agree_with_dynamic_path() {
    // Deterministic selection-bitmap case: multiple typed filters fused
    // with maps, so interior filters run as mask clears and survivors
    // compact exactly once at emission. Outputs must match the dynamic
    // path and the oracle at every batch size — including batch=1, where
    // single-row masks degenerate, and an all-shed batch (every row
    // filtered) which exercises the empty-after-compact path.
    let src = r#"
        v = bag(0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15);
        a = v.filter(|x| x % 2 == 0).map(|x| x + 100).filter(|x| x % 3 == 0).map(|x| x * 2);
        collect(a, "kept");
        none = v.filter(|x| x < 0).map(|x| x * 7);
        collect(none, "none");
    "#;
    let program = parse_and_lower(src).unwrap();
    let oracle = single_thread::run(&program, &Default::default()).unwrap();
    let (g_on, rep) =
        labyrinth::compile_with(&program, &gate_cfg(ColumnarGate::Always)).unwrap();
    assert!(rep.typed_edges > 0, "premise: the chains must be typed\n{}", rep.render());
    assert!(hot_edges_all_typed(&g_on), "premise: fully typed hot chains");
    let (g_off, _) =
        labyrinth::compile_with(&program, &gate_cfg(ColumnarGate::Never)).unwrap();
    assert!(!oracle.collected("kept").is_empty());
    assert!(oracle.collected("none").is_empty());
    for &batch in BATCH_SIZES {
        for (graph, mode) in [(&g_on, "columnar-on"), (&g_off, "columnar-off")] {
            let out = run(graph, &ExecConfig { workers: 2, batch, ..Default::default() })
                .unwrap_or_else(|e| panic!("{mode} batch={batch}: {e}"));
            for label in ["kept", "none"] {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(oracle.collected(label).to_vec()),
                    "label {label} {mode} batch={batch}"
                );
            }
        }
    }
}

#[test]
fn columnar_state_survives_midloop_panics() {
    for seed in 0..12u64 {
        let (src, _) = random_typed_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let (graph, _) =
            labyrinth::compile_with(&program, &gate_cfg(ColumnarGate::Always)).unwrap();
        for &checkpoint_every in &[Some(1u32), Some(3), None] {
            // Panic worker 1 mid-loop: with a checkpoint cadence the
            // resume restores operator state (reducer accumulators the
            // typed combiners built) from `InstanceSnapshot`s; without
            // one, the epoch retries from scratch.
            let cfg = ExecConfig {
                workers: 2,
                checkpoint_every,
                faults: Some(Arc::new(FaultPlan::new().panic_at(1, 2))),
                stall_timeout: Duration::from_secs(30),
                ..Default::default()
            };
            let out = run(&graph, &cfg).unwrap_or_else(|e| {
                panic!("seed {seed} ckpt={checkpoint_every:?}: {e}\n{src}")
            });
            for label in TYPED_PROGRAM_LABELS {
                assert_eq!(
                    multiset(out.collected(label).to_vec()),
                    multiset(oracle.collected(label).to_vec()),
                    "seed {seed} label {label} ckpt={checkpoint_every:?}\n{src}"
                );
            }
            assert_eq!(out.metrics.get("exec.faults_injected"), 1, "seed {seed}");
        }
    }
}

#[test]
fn columnar_survives_seeded_fault_schedules() {
    for seed in 12..24u64 {
        let (src, _) = random_typed_program(seed);
        let program = parse_and_lower(&src).unwrap();
        let oracle = single_thread::run(&program, &Default::default()).unwrap();
        let (graph, _) =
            labyrinth::compile_with(&program, &gate_cfg(ColumnarGate::Always)).unwrap();
        let cfg = ExecConfig {
            workers: 2,
            checkpoint_every: checkpoint_for_seed(seed),
            faults: Some(Arc::new(FaultPlan::seeded(seed))),
            stall_timeout: Duration::from_secs(30),
            ..Default::default()
        };
        let out = run(&graph, &cfg).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{src}"));
        for label in TYPED_PROGRAM_LABELS {
            assert_eq!(
                multiset(out.collected(label).to_vec()),
                multiset(oracle.collected(label).to_vec()),
                "seed {seed} label {label}\n{src}"
            );
        }
    }
}
