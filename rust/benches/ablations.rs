//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. **Path-encoding cost** (§6.3.1): transmitting bag IDs as path
//!    *lengths* with incremental block broadcasts is O(1) per block; the
//!    naive alternative (full path attached to every bag ID) is O(n) per
//!    bag and O(n²) total. We measure both encodings directly.
//! 2. **Batch size**: element batching on the simulated network vs
//!    per-element sends (the engine's hot-path knob).
//! 3. **Condition-node decision latency**: per-step coordination cost of
//!    the Labyrinth engine on an empty loop (the floor for Fig. 5).
//! 4. **Optimizer passes** (`opt::`): each pass toggled off against the
//!    full pipeline — hoisting on the in-loop invariant-join workload,
//!    fusion on a map/filter-chain microbenchmark, predicate pushdown on
//!    a selective post-join filter, and cost-driven build-side selection
//!    on a join whose program picked the pathological (large, varying)
//!    build side.

use labyrinth::bench_harness::{Bencher, Table};
use labyrinth::coord::ExecPath;
use labyrinth::exec::ExecConfig;
use labyrinth::frontend::builder::{udf1, udf2, ProgramBuilder};
use labyrinth::opt::OptConfig;
use labyrinth::programs;
use labyrinth::value::Value;
use std::time::Instant;

fn main() {
    let bench = Bencher::from_env(1, 5);

    // ---- 1. path encoding ------------------------------------------------
    let mut table = Table::new(
        "Ablation 1: execution-path encoding (work to track n appends)",
        "path length",
        vec!["incremental O(1)/block".into(), "naive full-path/bag".into()],
    );
    for n in [100usize, 1_000, 10_000] {
        let inc = bench.run(format!("incremental n={n}"), || {
            let mut p = ExecPath::new(4);
            p.append(0, &[0], false);
            for i in 1..n {
                // One block broadcast + one occurrence-index update.
                p.append(i, &[1 + (i % 2)], false);
            }
            std::hint::black_box(p.len());
        });
        let naive = bench.run(format!("naive n={n}"), || {
            // Naive: every new bag ID carries the whole path (clone).
            let mut path: Vec<usize> = vec![0];
            let mut total = 0usize;
            for i in 1..n {
                path.push(1 + (i % 2));
                let bag_id: Vec<usize> = path.clone(); // shipped per bag
                // Consume the whole id so the clone cannot be elided
                // (a real system would serialize all of it).
                total = total.wrapping_add(bag_id.iter().sum::<usize>());
                std::hint::black_box(&bag_id);
            }
            std::hint::black_box(total);
        });
        table.push_row(n.to_string(), vec![Some(inc.median()), Some(naive.median())]);
    }
    table.print();

    // ---- 2. batch size -----------------------------------------------------
    let program = programs::visit_count(10, "abl_");
    labyrinth::workload::VisitCountWorkload {
        days: 10,
        visits_per_day: 5_000,
        num_pages: 500,
        ..Default::default()
    }
    .register("abl_");
    let graph = labyrinth::compile(&program).unwrap();
    let mut table = Table::new(
        "Ablation 2: element batch size (Visit Count, 4 workers)",
        "batch",
        vec!["labyrinth".into()],
    );
    for batch in [1usize, 16, 64, 256, 1024] {
        let m = bench.run(format!("batch={batch}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: 4, batch, ..Default::default() },
            )
            .unwrap();
        });
        table.push_row(batch.to_string(), vec![Some(m.median())]);
    }
    table.print();

    // ---- 3. pure coordination floor ----------------------------------------
    // An empty loop: only the lifted counter, condition node, decision
    // round-trips, and Φ — the minimal per-step coordination cost.
    let steps = 2_000i64;
    let mut b = ProgramBuilder::new();
    let zero = b.scalar_i64(0);
    let i = b.declare_scalar("i", zero);
    b.while_(
        |b| b.scalar_lt_i64(i, steps),
        |b| {
            let i2 = b.scalar_add_i64(i, 1);
            b.assign_scalar(i, i2);
        },
    );
    let out = b.lift_scalar(i);
    b.collect(out, "i");
    let graph = labyrinth::compile(&b.finish()).unwrap();
    let t = Instant::now();
    let res = labyrinth::exec::run(&graph, &ExecConfig { workers: 4, ..Default::default() })
        .unwrap();
    let wall = t.elapsed();
    println!(
        "Ablation 3: empty-loop coordination floor: {steps} steps in {}, {:?}/step \
         (path length {})",
        labyrinth::util::fmt_duration(wall),
        wall / steps as u32,
        res.path_len
    );

    // ---- 4a. optimizer passes on the invariant-join workload ---------------
    // The in-loop Fig. 8 program: hoisting is the pass that matters here
    // (it re-enables the §7 build-side reuse); fuse/dce ride along.
    let w = labyrinth::workload::VisitCountWorkload {
        days: 10,
        visits_per_day: 1_000,
        num_pages: 4_000,
        ..Default::default()
    };
    w.register("abl4_");
    let in_loop = programs::visit_count_with_join_in_loop(10, "abl4_");
    let axes: Vec<(&str, OptConfig)> = vec![
        ("all-on", OptConfig::default()),
        ("no-hoist", OptConfig { hoist: false, ..OptConfig::default() }),
        ("no-fuse", OptConfig { fuse: false, ..OptConfig::default() }),
        ("no-dce", OptConfig { dce: false, ..OptConfig::default() }),
        ("none", OptConfig::none()),
    ];
    let mut table = Table::new(
        "Ablation 4a: optimizer passes (in-loop invariant join, 4 workers)",
        "passes",
        vec!["labyrinth".into()],
    );
    for (label, ocfg) in &axes {
        let (graph, _) = labyrinth::compile_with(&in_loop, ocfg).unwrap();
        let m = bench.run(format!("opt={label}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: 4, ..Default::default() },
            )
            .unwrap();
        });
        table.push_row(label.to_string(), vec![Some(m.median())]);
    }
    table.print();

    // ---- 4b. fusion on a map/filter chain ----------------------------------
    // A hot element-wise pipeline: 6 chained per-element operators over a
    // large bag. Fusion collapses the chain into one physical operator;
    // the delta is pure per-element dispatch + per-bag coordination.
    let elems = 200_000i64;
    let mut b = ProgramBuilder::new();
    let src = b.bag_lit((0..elems).map(Value::I64).collect());
    let chain0 = b.map(src, udf1(|v| Value::I64(v.as_i64() + 1)));
    let chain1 = b.map(chain0, udf1(|v| Value::I64(v.as_i64() * 3)));
    let chain2 = b.filter(chain1, udf1(|v| Value::Bool(v.as_i64() % 7 != 0)));
    let chain3 = b.map(chain2, udf1(|v| Value::I64(v.as_i64() - 2)));
    let chain4 = b.filter(chain3, udf1(|v| Value::Bool(v.as_i64() % 2 == 0)));
    let chain5 = b.map(chain4, udf1(|v| Value::pair(Value::I64(v.as_i64() % 1024), v.clone())));
    let reduced = b.reduce_by_key(chain5, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
    let n = b.count(reduced);
    let out = b.lift_scalar(n);
    b.collect(out, "n");
    let chain_prog = b.finish();
    let mut table = Table::new(
        "Ablation 4b: element-wise chain fusion (6-op chain, 200k elements, 4 workers)",
        "fusion",
        vec!["labyrinth".into()],
    );
    for (label, ocfg) in [
        ("fused", OptConfig::default()),
        ("unfused", OptConfig { fuse: false, ..OptConfig::default() }),
    ] {
        let (graph, report) = labyrinth::compile_with(&chain_prog, &ocfg).unwrap();
        if label == "fused" {
            assert!(report.fused_chains > 0, "chain must fuse:\n{}", report.render());
        }
        let m = bench.run(format!("chain {label}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: 4, ..Default::default() },
            )
            .unwrap();
        });
        table.push_row(label.to_string(), vec![Some(m.median())]);
    }
    table.print();

    // ---- 4c. predicate pushdown below an in-loop join ----------------------
    // A selective filter (1/13) above the join: pushed below, the probe
    // side shrinks before it is hashed and shipped every iteration.
    let registry = labyrinth::workload::registry::global();
    registry.put("abl_pd_facts", (0..50_000i64).map(Value::I64).collect());
    registry.put("abl_pd_dim", (0..4_000i64).map(Value::I64).collect());
    let pd_src = r#"
        dim = source("abl_pd_dim").map(|v| pair(v % 512, v));
        i = 0;
        while (i < 10) {
            facts = source("abl_pd_facts").map(|v| pair(v % 512, v + i));
            j = facts.join(dim);
            hot = j.filter(|p| snd(snd(p)) % 13 == 0);
            agg = hot.map(|p| pair(fst(p), 1)).reduceByKey(|a, b| a + b);
            collect(agg, "agg");
            i = i + 1;
        }
    "#;
    let pd_prog = labyrinth::frontend::parse_and_lower(pd_src).unwrap();
    let mut table = Table::new(
        "Ablation 4c: predicate pushdown (selective post-join filter, 4 workers)",
        "pushdown",
        vec!["labyrinth".into()],
    );
    for (label, ocfg) in [
        ("pushed", OptConfig::default()),
        ("unpushed", OptConfig { pushdown: false, ..OptConfig::default() }),
    ] {
        let (graph, report) = labyrinth::compile_with(&pd_prog, &ocfg).unwrap();
        if label == "pushed" {
            assert!(report.pushed_filters > 0, "filter must push:\n{}", report.render());
        }
        let m = bench.run(format!("pushdown {label}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: 4, ..Default::default() },
            )
            .unwrap();
        });
        table.push_row(label.to_string(), vec![Some(m.median())]);
    }
    table.print();
    registry.clear_prefix("abl_pd_");

    // ---- 4d. join build-side selection -------------------------------------
    // The program builds on the large, loop-varying side (`joinBuild`
    // makes the receiver the build side); the cost model should flip the
    // build to the small invariant dimension table, re-enabling the §7
    // cross-step hash-table reuse.
    registry.put("abl_js_facts", (0..50_000i64).map(Value::I64).collect());
    registry.put("abl_js_dim", (0..4_000i64).map(Value::I64).collect());
    let js_src = r#"
        dim = source("abl_js_dim").map(|v| pair(v % 256, v));
        i = 0;
        while (i < 10) {
            facts = source("abl_js_facts").map(|v| pair(v % 256, v + i));
            j = facts.joinBuild(dim);
            agg = j.map(|p| pair(fst(p), 1)).reduceByKey(|a, b| a + b);
            collect(agg, "agg");
            i = i + 1;
        }
    "#;
    let js_prog = labyrinth::frontend::parse_and_lower(js_src).unwrap();
    let mut table = Table::new(
        "Ablation 4d: cost-driven join build-side selection (4 workers)",
        "join sides",
        vec!["labyrinth".into()],
    );
    for (label, ocfg) in [
        ("cost-chosen", OptConfig::default()),
        ("as-written", OptConfig { join_sides: false, ..OptConfig::default() }),
    ] {
        let (graph, report) = labyrinth::compile_with(&js_prog, &ocfg).unwrap();
        if label == "cost-chosen" {
            assert!(report.join_flips > 0, "build side must flip:\n{}", report.render());
        }
        let m = bench.run(format!("joinside {label}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: 4, ..Default::default() },
            )
            .unwrap();
        });
        table.push_row(label.to_string(), vec![Some(m.median())]);
    }
    table.print();
    registry.clear_prefix("abl_js_");
}
