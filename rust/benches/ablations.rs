//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. **Path-encoding cost** (§6.3.1): transmitting bag IDs as path
//!    *lengths* with incremental block broadcasts is O(1) per block; the
//!    naive alternative (full path attached to every bag ID) is O(n) per
//!    bag and O(n²) total. We measure both encodings directly.
//! 2. **Batch size**: element batching on the simulated network vs
//!    per-element sends (the engine's hot-path knob).
//! 3. **Condition-node decision latency**: per-step coordination cost of
//!    the Labyrinth engine on an empty loop (the floor for Fig. 5).

use labyrinth::bench_harness::{Bencher, Table};
use labyrinth::coord::ExecPath;
use labyrinth::exec::ExecConfig;
use labyrinth::frontend::builder::ProgramBuilder;
use labyrinth::programs;
use std::time::Instant;

fn main() {
    let bench = Bencher::from_env(1, 5);

    // ---- 1. path encoding ------------------------------------------------
    let mut table = Table::new(
        "Ablation 1: execution-path encoding (work to track n appends)",
        "path length",
        vec!["incremental O(1)/block".into(), "naive full-path/bag".into()],
    );
    for n in [100usize, 1_000, 10_000] {
        let inc = bench.run(format!("incremental n={n}"), || {
            let mut p = ExecPath::new(4);
            p.append(0, &[0], false);
            for i in 1..n {
                // One block broadcast + one occurrence-index update.
                p.append(i, &[1 + (i % 2)], false);
            }
            std::hint::black_box(p.len());
        });
        let naive = bench.run(format!("naive n={n}"), || {
            // Naive: every new bag ID carries the whole path (clone).
            let mut path: Vec<usize> = vec![0];
            let mut total = 0usize;
            for i in 1..n {
                path.push(1 + (i % 2));
                let bag_id: Vec<usize> = path.clone(); // shipped per bag
                // Consume the whole id so the clone cannot be elided
                // (a real system would serialize all of it).
                total = total.wrapping_add(bag_id.iter().sum::<usize>());
                std::hint::black_box(&bag_id);
            }
            std::hint::black_box(total);
        });
        table.push_row(n.to_string(), vec![Some(inc.median()), Some(naive.median())]);
    }
    table.print();

    // ---- 2. batch size -----------------------------------------------------
    let program = programs::visit_count(10, "abl_");
    labyrinth::workload::VisitCountWorkload {
        days: 10,
        visits_per_day: 5_000,
        num_pages: 500,
        ..Default::default()
    }
    .register("abl_");
    let graph = labyrinth::compile(&program).unwrap();
    let mut table = Table::new(
        "Ablation 2: element batch size (Visit Count, 4 workers)",
        "batch",
        vec!["labyrinth".into()],
    );
    for batch in [1usize, 16, 64, 256, 1024] {
        let m = bench.run(format!("batch={batch}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: 4, batch, ..Default::default() },
            )
            .unwrap();
        });
        table.push_row(batch.to_string(), vec![Some(m.median())]);
    }
    table.print();

    // ---- 3. pure coordination floor ----------------------------------------
    // An empty loop: only the lifted counter, condition node, decision
    // round-trips, and Φ — the minimal per-step coordination cost.
    let steps = 2_000i64;
    let mut b = ProgramBuilder::new();
    let zero = b.scalar_i64(0);
    let i = b.declare_scalar("i", zero);
    b.while_(
        |b| b.scalar_lt_i64(i, steps),
        |b| {
            let i2 = b.scalar_add_i64(i, 1);
            b.assign_scalar(i, i2);
        },
    );
    let out = b.lift_scalar(i);
    b.collect(out, "i");
    let graph = labyrinth::compile(&b.finish()).unwrap();
    let t = Instant::now();
    let res = labyrinth::exec::run(&graph, &ExecConfig { workers: 4, ..Default::default() })
        .unwrap();
    let wall = t.elapsed();
    println!(
        "Ablation 3: empty-loop coordination floor: {steps} steps in {}, {:?}/step \
         (path length {})",
        labyrinth::util::fmt_duration(wall),
        wall / steps as u32,
        res.path_len
    );
}
