//! Data-plane throughput: elements/sec for map, the fused map/filter
//! chain, flatMap, hash-join probe, and reduceByKey at workers ∈
//! {1, 2, 4}, plus the batched-vs-element-path before/after series.
//!
//! Acceptance target: the batched fused chain sustains ≥ 2x the
//! elements/sec of the legacy element-at-a-time path (recorded in
//! `BENCH_throughput.json`). `LABY_BENCH_QUICK=1` shrinks all counts
//! (CI smoke).

fn main() {
    let smoke = std::env::var("LABY_BENCH_QUICK").ok().as_deref() == Some("1");
    labyrinth::bench_throughput::throughput_benchmark(smoke);
}
