//! Fig. 7 — strong scaling on nested-loop PageRank (outer loop over daily
//! transition logs, inner fixpoint). Three implementations:
//!
//!   * Labyrinth: the whole nested program is ONE cyclic job;
//!   * Flink-hybrid: the inner fixpoint runs in-dataflow (supersteps), but
//!     every outer step still launches a separate job (the paper's Flink:
//!     "only in the case of fixpoint iterations");
//!   * Spark-like: every inner AND outer step is a separate job.
//!
//! Paper result: Flink ≈ Labyrinth (outer-loop scheduling amortized by the
//! inner work), Spark ~4.6× slower at 25 workers and stops scaling ≈ 9.

use labyrinth::baselines::{fixpoint, separate_jobs};
use labyrinth::bench_harness::{Bencher, Table};
use labyrinth::exec::ExecConfig;
use labyrinth::programs;
use labyrinth::sched::LatencyModel;
use labyrinth::value::Value;
use labyrinth::workload::PageRankWorkload;

fn main() {
    let quick = std::env::var("LABY_BENCH_QUICK").is_ok();
    let workers: Vec<usize> = if quick { vec![1, 4, 25] } else { vec![1, 2, 5, 10, 25] };
    let days = 3usize;
    let inner = 10i64;
    let pages = 200usize;
    let w = PageRankWorkload {
        days,
        num_pages: pages,
        edges_per_day: if quick { 1_000 } else { 3_000 },
        ..Default::default()
    };

    // Register weighted adjacency per day (shared by all implementations).
    let mut per_day_edges: Vec<Vec<(usize, usize)>> = Vec::new();
    for day in 1..=days {
        let edges = w.day_edges(day);
        let pairs: Vec<(usize, usize)> = edges
            .iter()
            .map(|v| (v.key().as_i64() as usize, v.val().as_i64() as usize))
            .collect();
        let mut outdeg = vec![0usize; pages];
        for &(s, _) in &pairs {
            outdeg[s] += 1;
        }
        let adj: Vec<Value> = pairs
            .iter()
            .map(|&(s, d)| {
                Value::pair(
                    Value::I64(s as i64),
                    Value::pair(Value::I64(d as i64), Value::F64(1.0 / outdeg[s] as f64)),
                )
            })
            .collect();
        labyrinth::workload::registry::global().put(format!("fig7_adj{day}"), adj);
        per_day_edges.push(pairs);
    }

    let program = programs::pagerank_nested(days as i64, inner, pages, "fig7_");
    let graph = labyrinth::compile(&program).unwrap();
    let bench = Bencher::from_env(1, 5);
    let mut table = Table::new(
        format!("Fig 7: nested PageRank ({days} days, {inner} inner iters, {pages} pages)"),
        "workers",
        vec!["labyrinth".into(), "flink-hybrid".into(), "spark-sep".into()],
    );

    for &wk in &workers {
        let laby = bench.run(format!("labyrinth w={wk}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig {
                    workers: wk,
                    sched: Some(LatencyModel::flink_like()),
                    ..Default::default()
                },
            )
            .unwrap();
        });

        // Flink-hybrid: one scheduled job per OUTER day; inner fixpoint
        // runs as supersteps on persistent workers.
        let model = LatencyModel::flink_like();
        let edges_ref = &per_day_edges;
        let flink = bench.run(format!("flink-hybrid w={wk}"), || {
            for day_edges in edges_ref {
                // job launch for this day's dataflow (read + iterate + sink)
                model.simulate_job_launch(4, wk);
                fixpoint::pagerank_fixpoint(day_edges, pages, inner as usize, wk);
            }
        });

        // Spark-like: every inner step is a separate job too.
        let spark = bench.run(format!("spark-sep w={wk}"), || {
            separate_jobs::run(&program, &separate_jobs::SeparateJobsConfig::spark(wk))
                .unwrap();
        });

        table.push_row(
            wk.to_string(),
            vec![Some(laby.median()), Some(flink.median()), Some(spark.median())],
        );
    }
    table.print();
    println!("(paper: Flink ≈ Labyrinth; Spark ~4.6x slower at 25 workers)");
}
