//! Fig. 6 — strong scaling on the Visit Count program (no invariant join):
//! fixed total input, varying worker count, five implementations:
//! Labyrinth pipelined (default), Labyrinth with per-step barriers,
//! Flink-like and Spark-like separate jobs, and the single-threaded COST
//! baseline.
//!
//! Paper result: at 25 workers the separate-jobs systems fall ~2× behind
//! Labyrinth (scheduling overhead grows with the cluster), pipelining buys
//! a further ~3×, and Labyrinth passes the single-threaded baseline at ~5
//! machines. NOTE: this host has 1 physical core, so worker "scaling" here
//! isolates the *overhead* component (flat-to-rising curves); the
//! separate-jobs-vs-Labyrinth gap is the reproduction target
//! (EXPERIMENTS.md discusses this).

use labyrinth::baselines::{separate_jobs, single_thread};
use labyrinth::bench_harness::{Bencher, Table};
use labyrinth::exec::{ExecConfig, ExecMode};
use labyrinth::programs;
use labyrinth::workload::VisitCountWorkload;

fn main() {
    let quick = std::env::var("LABY_BENCH_QUICK").is_ok();
    let workers: Vec<usize> = if quick { vec![1, 4, 25] } else { vec![1, 2, 5, 10, 25] };
    let days = 30;
    let w = VisitCountWorkload {
        days,
        visits_per_day: if quick { 1_000 } else { 4_000 },
        num_pages: 500,
        ..Default::default()
    };
    w.register("fig6_");
    let program = programs::visit_count(days as i64, "fig6_");
    let bench = Bencher::from_env(1, 5);

    // Single-threaded baseline (worker-count independent).
    let st = bench.run("single-threaded", || {
        single_thread::run(&program, &Default::default()).unwrap();
    });

    let graph = labyrinth::compile(&program).unwrap();
    let mut table = Table::new(
        format!(
            "Fig 6: Visit Count strong scaling ({days} days x {} visits)",
            w.visits_per_day
        ),
        "workers",
        vec![
            "laby-pipelined".into(),
            "laby-barrier".into(),
            "flink-sep".into(),
            "spark-sep".into(),
            "single-thread".into(),
        ],
    );

    for &wk in &workers {
        let pipelined = bench.run(format!("laby-pipelined w={wk}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig {
                    workers: wk,
                    sched: Some(labyrinth::sched::LatencyModel::flink_like()),
                    ..Default::default()
                },
            )
            .unwrap();
        });
        let barrier = bench.run(format!("laby-barrier w={wk}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig {
                    workers: wk,
                    mode: ExecMode::Barrier,
                    sched: Some(labyrinth::sched::LatencyModel::flink_like()),
                    ..Default::default()
                },
            )
            .unwrap();
        });
        let flink = bench.run(format!("flink-sep w={wk}"), || {
            separate_jobs::run(&program, &separate_jobs::SeparateJobsConfig::flink(wk)).unwrap();
        });
        let spark = bench.run(format!("spark-sep w={wk}"), || {
            separate_jobs::run(&program, &separate_jobs::SeparateJobsConfig::spark(wk)).unwrap();
        });
        table.push_row(
            wk.to_string(),
            vec![
                Some(pipelined.median()),
                Some(barrier.median()),
                Some(flink.median()),
                Some(spark.median()),
                Some(st.median()),
            ],
        );
    }
    table.print();
    println!(
        "(single-thread column repeated per row for crossover comparison; 1-core host)"
    );
}
