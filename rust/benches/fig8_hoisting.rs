//! Fig. 8 — loop-invariant hoisting: Visit Count WITH the invariant
//! attribute join, sweeping the data scale at fixed workers. Six lines:
//!
//!   * labyrinth          — hand-hoisted program (attrs outside the loop),
//!                          §7 build-side reuse ON
//!   * laby-hoist         — attrs written INSIDE the loop, the `opt::hoist`
//!                          pass lifts it into the loop preamble; must
//!                          match (or beat) the hand-hoisted line
//!   * laby-noopt         — same in-loop program with the optimizer OFF:
//!                          the build side recomputes and the hash table
//!                          rebuilds every step
//!   * laby-noreuse       — hand-hoisted program, runtime reuse OFF
//!                          (rebuild per step, like §9.4's ablation)
//!   * flink-sep / spark-sep — separate jobs rebuild per step by
//!                          construction
//!
//! Plus the speculation ablation on a ZERO-TRIP variant of the same
//! program (`days = 0` — the loop never runs):
//!
//!   * ztrip-gated        — default optimizer: the `opt::cost` trip
//!                          estimate is Exact(0), the speculative source
//!                          chain stays in the loop, and the run pays
//!                          nothing for it
//!   * ztrip-spec         — `opt.speculate = always` (the old always-on
//!                          contract): the hoisted source materializes
//!                          the full attrs dataset at loop entry even
//!                          though no iteration ever consumes it
//!
//! Paper result (log-log): ~3× speedup at the largest scale; negligible at
//! the smallest scales where per-step overhead dominates. The gated-hoist
//! line must match laby-hoist (the gate clears easily at 10 trips), while
//! ztrip-gated must not scale with the attrs size the way ztrip-spec does.

use labyrinth::baselines::separate_jobs;
use labyrinth::bench_harness::{Bencher, Table};
use labyrinth::exec::ExecConfig;
use labyrinth::frontend::Rhs;
use labyrinth::opt::{OptConfig, Speculate};
use labyrinth::programs;
use labyrinth::workload::VisitCountWorkload;

const WORKERS: usize = 4;

fn main() {
    let quick = std::env::var("LABY_BENCH_QUICK").is_ok();
    let scales: Vec<usize> = if quick { vec![1, 4] } else { vec![1, 2, 4, 8, 16] };
    let days = 10;
    let bench = Bencher::from_env(1, 5);
    let mut table = Table::new(
        "Fig 8: loop-invariant hoisting + hash-join reuse vs data scale (4 workers)",
        "scale",
        vec![
            "labyrinth".into(),
            "laby-hoist".into(),
            "laby-noopt".into(),
            "laby-noreuse".into(),
            "ztrip-gated".into(),
            "ztrip-spec".into(),
            "flink-sep".into(),
            "spark-sep".into(),
        ],
    );

    for &scale in &scales {
        // The invariant dataset (attrs, the build side) is much larger
        // than each day's visits — the regime where hoisting matters.
        let w = VisitCountWorkload {
            days,
            visits_per_day: 500 * scale,
            num_pages: 4_000 * scale,
            ..Default::default()
        };
        let prefix = format!("fig8_{scale}_");
        w.register(&prefix);
        let program = programs::visit_count_with_join(days as i64, &prefix);
        let graph = labyrinth::compile(&program).unwrap();
        // The pass-driven path: the same workload with the invariant
        // source written inside the loop, hoisted by the compiler.
        let in_loop = programs::visit_count_with_join_in_loop(days as i64, &prefix);
        let (hoisted_graph, report) =
            labyrinth::compile_with(&in_loop, &OptConfig::default()).unwrap();
        assert!(report.hoisted > 0, "hoisting pass must fire:\n{}", report.render());
        let (raw_graph, _) = labyrinth::compile_with(&in_loop, &OptConfig::none()).unwrap();
        // Zero-trip variant: same program shape, loop bound 0. The cost
        // gate must keep the speculative attrs chain in the (dead) loop;
        // `speculate = always` restores the old behavior for comparison.
        let ztrip = programs::visit_count_with_join_in_loop(0, &prefix);
        let (zt_gated_graph, zt_report) =
            labyrinth::compile_with(&ztrip, &OptConfig::default()).unwrap();
        assert!(
            zt_gated_graph.nodes.iter().all(
                |n| !(matches!(n.op, Rhs::NamedSource(_)) && n.hoisted_from.is_some())
            ),
            "gate must keep the zero-trip source lazy:\n{}",
            zt_report.render()
        );
        let (zt_spec_graph, _) = labyrinth::compile_with(
            &ztrip,
            &OptConfig { speculate: Speculate::Always, ..OptConfig::default() },
        )
        .unwrap();

        let reuse = bench.run(format!("labyrinth scale={scale}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: WORKERS, ..Default::default() },
            )
            .unwrap();
        });
        let hoist = bench.run(format!("laby-hoist scale={scale}"), || {
            labyrinth::exec::run(
                &hoisted_graph,
                &ExecConfig { workers: WORKERS, ..Default::default() },
            )
            .unwrap();
        });
        let noopt = bench.run(format!("laby-noopt scale={scale}"), || {
            labyrinth::exec::run(
                &raw_graph,
                &ExecConfig { workers: WORKERS, ..Default::default() },
            )
            .unwrap();
        });
        let noreuse = bench.run(format!("laby-noreuse scale={scale}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: WORKERS, reuse_state: false, ..Default::default() },
            )
            .unwrap();
        });
        let zt_gated = bench.run(format!("ztrip-gated scale={scale}"), || {
            labyrinth::exec::run(
                &zt_gated_graph,
                &ExecConfig { workers: WORKERS, ..Default::default() },
            )
            .unwrap();
        });
        let zt_spec = bench.run(format!("ztrip-spec scale={scale}"), || {
            labyrinth::exec::run(
                &zt_spec_graph,
                &ExecConfig { workers: WORKERS, ..Default::default() },
            )
            .unwrap();
        });
        let flink = bench.run(format!("flink-sep scale={scale}"), || {
            separate_jobs::run(&program, &separate_jobs::SeparateJobsConfig::flink(WORKERS))
                .unwrap();
        });
        let spark = bench.run(format!("spark-sep scale={scale}"), || {
            separate_jobs::run(&program, &separate_jobs::SeparateJobsConfig::spark(WORKERS))
                .unwrap();
        });
        table.push_row(
            format!("x{scale}"),
            vec![
                Some(reuse.median()),
                Some(hoist.median()),
                Some(noopt.median()),
                Some(noreuse.median()),
                Some(zt_gated.median()),
                Some(zt_spec.median()),
                Some(flink.median()),
                Some(spark.median()),
            ],
        );
        // Free the registered datasets of this scale.
        labyrinth::workload::registry::global().clear_prefix(&prefix);
    }
    table.print();
    println!(
        "(paper: reuse ~3x at the largest scale; laby-hoist = compiler-hoisted in-loop \
         program, expected to track the hand-hoisted labyrinth line; ztrip-gated = \
         zero-trip loop under the default cost gate, expected flat vs scale, while \
         ztrip-spec pays the speculated attrs materialization)"
    );
}
