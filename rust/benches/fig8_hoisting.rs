//! Fig. 8 — loop-invariant hoisting: Visit Count WITH the invariant
//! attribute join, sweeping the data scale at fixed workers. Four lines:
//!
//!   * labyrinth          — §7 build-side reuse ON (build the attrs hash
//!                          table once, probe it every step)
//!   * laby-noreuse       — reuse OFF (rebuild per step, like §9.4's ablation)
//!   * flink-sep / spark-sep — separate jobs rebuild the table per step by
//!                          construction
//!
//! Paper result (log-log): ~3× speedup at the largest scale; negligible at
//! the smallest scales where per-step overhead dominates.

use labyrinth::baselines::separate_jobs;
use labyrinth::bench_harness::{Bencher, Table};
use labyrinth::exec::ExecConfig;
use labyrinth::programs;
use labyrinth::workload::VisitCountWorkload;

const WORKERS: usize = 4;

fn main() {
    let quick = std::env::var("LABY_BENCH_QUICK").is_ok();
    let scales: Vec<usize> = if quick { vec![1, 4] } else { vec![1, 2, 4, 8, 16] };
    let days = 10;
    let bench = Bencher::from_env(1, 5);
    let mut table = Table::new(
        "Fig 8: loop-invariant hash-join reuse vs data scale (4 workers)",
        "scale",
        vec![
            "labyrinth".into(),
            "laby-noreuse".into(),
            "flink-sep".into(),
            "spark-sep".into(),
        ],
    );

    for &scale in &scales {
        // The invariant dataset (attrs, the build side) is much larger
        // than each day's visits — the regime where hoisting matters.
        let w = VisitCountWorkload {
            days,
            visits_per_day: 500 * scale,
            num_pages: 4_000 * scale,
            ..Default::default()
        };
        let prefix = format!("fig8_{scale}_");
        w.register(&prefix);
        let program = programs::visit_count_with_join(days as i64, &prefix);
        let graph = labyrinth::compile(&program).unwrap();

        let reuse = bench.run(format!("labyrinth scale={scale}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: WORKERS, ..Default::default() },
            )
            .unwrap();
        });
        let noreuse = bench.run(format!("laby-noreuse scale={scale}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: WORKERS, reuse_state: false, ..Default::default() },
            )
            .unwrap();
        });
        let flink = bench.run(format!("flink-sep scale={scale}"), || {
            separate_jobs::run(&program, &separate_jobs::SeparateJobsConfig::flink(WORKERS))
                .unwrap();
        });
        let spark = bench.run(format!("spark-sep scale={scale}"), || {
            separate_jobs::run(&program, &separate_jobs::SeparateJobsConfig::spark(WORKERS))
                .unwrap();
        });
        table.push_row(
            format!("x{scale}"),
            vec![
                Some(reuse.median()),
                Some(noreuse.median()),
                Some(flink.median()),
                Some(spark.median()),
            ],
        );
        // Free the registered datasets of this scale.
        labyrinth::workload::registry::global().clear_prefix(&prefix);
    }
    table.print();
    println!("(paper: reuse ~3x at the largest scale, negligible at the smallest)");
}
