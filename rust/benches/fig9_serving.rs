//! Fig. 9 (ours — the paper has no serving figure): job-service
//! throughput. Per-job submission latency under three control-plane
//! regimes — cold compile+spawn per job, cached plan template with a
//! fresh worker pool per job, and the full `serve::JobService` path
//! (cached template + persistent warm pool) — plus jobs/sec under N
//! concurrent clients as the slot count grows.
//!
//! Acceptance target: cached-template + warm-pool submission at least
//! 10x lower latency than cold compile+spawn, and throughput scaling
//! with job slots. `LABY_BENCH_QUICK=1` shrinks all counts (CI smoke).

fn main() {
    let smoke = std::env::var("LABY_BENCH_QUICK").ok().as_deref() == Some("1");
    labyrinth::serve::bench::serving_benchmark(smoke);
}
