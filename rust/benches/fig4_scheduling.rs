//! Fig. 4 — scheduling-overhead microbenchmark: the cost of launching one
//! minimal dataflow job (a parallel collection only, no I/O) as a function
//! of worker count, for the Spark-like and Flink-like scheduler models,
//! vs Labyrinth's one-time job launch.
//!
//! Paper result: linear growth, reaching 254 ms (Spark) / 376 ms (Flink)
//! at 25 workers. Our substrate uses µs-scale RPC latencies (DESIGN.md §2)
//! so absolute numbers are ~1000× smaller; the *linearity* and the
//! Spark-vs-Flink ordering are the reproduction targets.

use labyrinth::bench_harness::{Bencher, Table};
use labyrinth::sched::LatencyModel;

fn main() {
    let workers = [1usize, 2, 5, 10, 15, 20, 25];
    let bench = Bencher::from_env(2, 9);
    // The minimal job: one operator (the parallel collection).
    let ops = 1;

    let mut table = Table::new(
        "Fig 4: per-job scheduling overhead (minimal job, 1 operator)",
        "workers",
        vec!["spark-like".into(), "flink-like".into()],
    );
    let spark = LatencyModel::spark_like();
    let flink = LatencyModel::flink_like();
    let mut samples = Vec::new();
    for &w in &workers {
        let ms = bench.run(format!("spark-like w={w}"), || {
            spark.simulate_job_launch(ops, w);
        });
        let mf = bench.run(format!("flink-like w={w}"), || {
            flink.simulate_job_launch(ops, w);
        });
        samples.push((w, ms.median(), mf.median()));
        table.push_row(w.to_string(), vec![Some(ms.median()), Some(mf.median())]);
    }
    table.print();

    // Linearity check (paper: "increased linearly"): compare the measured
    // growth from 5 to 25 workers with the ideal 5x of the variable part.
    let at = |w: usize| samples.iter().find(|(x, _, _)| *x == w).unwrap();
    let (_, s5, f5) = at(5);
    let (_, s25, f25) = at(25);
    println!(
        "growth 5->25 workers: spark {:.2}x, flink {:.2}x (variable part ideal: 5x)",
        s25.as_secs_f64() / s5.as_secs_f64(),
        f25.as_secs_f64() / f5.as_secs_f64()
    );
    println!(
        "modelled at 25 workers: spark {:?}, flink {:?} (paper: 254 ms / 376 ms on real GbE)",
        spark.job_launch_cost(ops, 25),
        flink.job_launch_cost(ops, 25)
    );
}
