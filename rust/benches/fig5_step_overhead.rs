//! Fig. 5 — iteration-step-overhead microbenchmark (log-log in the paper):
//! a loop of `bag.map(x => x + 1)` over a 200-element bag, with a pipeline
//! breaker per step, under five implementations:
//!
//!   * separate jobs, Spark-like        (new job every step)
//!   * separate jobs, Flink-like        (new job + collect-to-driver)
//!   * fixpoint supersteps (Flink/Naiad in-dataflow iterate)
//!   * Labyrinth                        (single cyclic job, §6 coordination)
//!   * Labyrinth + XLA artifact map     (per-step compute through PJRT)
//!
//! Paper result: the separate-jobs lines sit ~2 orders of magnitude above
//! the in-dataflow cluster (Flink-iterate ≈ Naiad ≈ TensorFlow ≈
//! Labyrinth). The reproduction target is that gap and the near-constant
//! per-step cost of the in-dataflow implementations.

use labyrinth::baselines::{fixpoint, graph_jobs, separate_jobs};
use labyrinth::bench_harness::{Bencher, Table};
use labyrinth::exec::{ExecConfig, ExecMode};
use labyrinth::programs;
use labyrinth::value::Value;
use std::sync::Arc;
use std::time::Duration;

const WORKERS: usize = 4;
const BAG: usize = 200;

fn main() {
    let steps_sweep: Vec<i64> = if std::env::var("LABY_BENCH_QUICK").is_ok() {
        vec![10, 50, 100]
    } else {
        vec![10, 30, 100, 300, 1000]
    };
    let bench = Bencher::from_env(1, 5);

    let series = vec![
        "spark-sep".to_string(),
        "flink-sep".to_string(),
        "fixpoint-superstep".to_string(),
        "labyrinth".to_string(),
        "labyrinth-barrier".to_string(),
        "spark-sep-opt".to_string(),
    ];
    let mut table = Table::new(
        "Fig 5: time per run vs iteration steps (200-element bag, 4 workers)",
        "steps",
        series.clone(),
    );

    let mut per_step: Vec<(String, Duration, Duration)> = Vec::new();
    let mut firsts: Vec<Vec<Duration>> = vec![Vec::new(); series.len()];

    for &steps in &steps_sweep {
        let program = programs::step_overhead_microbench(steps, BAG);
        let mut cells = Vec::new();

        // Separate jobs.
        let m = bench.run(format!("spark-sep steps={steps}"), || {
            let cfg = separate_jobs::SeparateJobsConfig::spark(WORKERS);
            separate_jobs::run(&program, &cfg).unwrap();
        });
        cells.push(Some(m.median()));
        firsts[0].push(m.median());
        let m = bench.run(format!("flink-sep steps={steps}"), || {
            let cfg = separate_jobs::SeparateJobsConfig::flink(WORKERS);
            separate_jobs::run(&program, &cfg).unwrap();
        });
        cells.push(Some(m.median()));
        firsts[1].push(m.median());

        // Fixpoint supersteps (map + keyed keep-first as pipeline breaker).
        let initial: Vec<Value> = (0..BAG as i64)
            .map(|k| Value::pair(Value::I64(k % 64), Value::I64(k)))
            .collect();
        let spec = fixpoint::StepSpec {
            scatter: Arc::new(|v: &Value, _| {
                let Value::Pair(p) = v else { unreachable!() };
                vec![Value::pair(p.0.clone(), Value::I64(p.1.as_i64() + 1))]
            }),
            combine: Some(labyrinth::frontend::Udf2::new("keep", |a, _b| a.clone())),
        };
        let m = bench.run(format!("fixpoint steps={steps}"), || {
            fixpoint::Fixpoint::new(WORKERS).run(initial.clone(), steps as usize, &spec);
        });
        cells.push(Some(m.median()));
        firsts[2].push(m.median());

        // Labyrinth (single cyclic job).
        let graph = labyrinth::compile(&program).unwrap();
        let m = bench.run(format!("labyrinth steps={steps}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: WORKERS, ..Default::default() },
            )
            .unwrap();
        });
        cells.push(Some(m.median()));
        firsts[3].push(m.median());

        let m = bench.run(format!("labyrinth-barrier steps={steps}"), || {
            labyrinth::exec::run(
                &graph,
                &ExecConfig { workers: WORKERS, mode: ExecMode::Barrier, ..Default::default() },
            )
            .unwrap();
        });
        cells.push(Some(m.median()));
        firsts[4].push(m.median());

        // Separate jobs over the OPTIMIZED dataflow graph (graph_jobs):
        // same per-step job submission model, but fused chains / DCE /
        // hoisted preambles from `opt::optimize` apply — the optimizer's
        // wins are visible inside the separate-jobs regime too.
        let m = bench.run(format!("spark-sep-opt steps={steps}"), || {
            let cfg = separate_jobs::SeparateJobsConfig::spark(WORKERS);
            graph_jobs::run_graph(&graph, &cfg).unwrap();
        });
        cells.push(Some(m.median()));
        firsts[5].push(m.median());

        table.push_row(steps.to_string(), cells);
    }
    table.print();

    // Derived per-step overhead: slope between the smallest and largest
    // sweep points (removes constant startup cost).
    println!("== per-step overhead (slope between extremes) ==");
    let lo = steps_sweep[0] as f64;
    let hi = *steps_sweep.last().unwrap() as f64;
    for (i, name) in series.iter().enumerate() {
        let t_lo = firsts[i].first().unwrap().as_secs_f64();
        let t_hi = firsts[i].last().unwrap().as_secs_f64();
        let slope = ((t_hi - t_lo) / (hi - lo)).max(0.0);
        per_step.push((
            name.clone(),
            Duration::from_secs_f64(slope),
            Duration::from_secs_f64(t_hi),
        ));
        println!("{name:<22} {:>12}/step", labyrinth::util::fmt_duration(Duration::from_secs_f64(slope)));
    }
    let sep = per_step[0].1.as_secs_f64().min(per_step[1].1.as_secs_f64());
    let laby = per_step[3].1.as_secs_f64().max(1e-9);
    println!(
        "separate-jobs / labyrinth per-step ratio: {:.0}x (paper: ~2 orders of magnitude)",
        sep / laby
    );
}
