//! Quickstart: build an imperative program with the Rust builder API,
//! compile it through CFG → SSA → dataflow, and run it on the Labyrinth
//! engine. The loop's exit condition depends on data computed *inside*
//! the loop — the case where separate-jobs systems pay a scheduling round
//! per step and Labyrinth does not.
//!
//!   cargo run --release --example quickstart

use labyrinth::prelude::*;

fn main() -> labyrinth::Result<()> {
    // values = bag(1..=8); total = 0;
    // while (total < 100) { values = values.map(+1); total = sum(values); }
    let mut b = ProgramBuilder::new();
    let init = b.bag_lit((1..=8).map(Value::I64).collect());
    let values = b.declare_bag("values", init);
    let zero = b.scalar_i64(0);
    let total = b.declare_scalar("total", zero);
    b.while_(
        |b| {
            let c = b.scalar_lt_i64(total, 100);
            c
        },
        |b| {
            let bumped = b.map(values, udf1(|v| Value::I64(v.as_i64() + 1)));
            b.assign_bag(values, bumped);
            let sum = b.reduce(values, udf2(|a, c| Value::I64(a.as_i64() + c.as_i64())));
            b.assign_scalar(total, sum);
        },
    );
    b.collect(values, "values");
    let program = b.finish();

    println!("-- imperative IR --\n{}", program.listing());
    let graph = labyrinth::compile(&program)?;
    println!("-- SSA --\n{}", graph.ssa_listing);
    println!(
        "-- dataflow: {} nodes, {} condition node(s) --",
        graph.num_nodes(),
        graph.condition_nodes().len()
    );

    let out = run(&graph, &ExecConfig { workers: 4, ..Default::default() })?;
    let mut vals: Vec<i64> = out.collected("values").iter().map(|v| v.as_i64()).collect();
    vals.sort();
    println!("final values: {vals:?}");
    println!(
        "executed {} control-flow steps in {} as ONE dataflow job",
        out.path_len,
        labyrinth::util::fmt_duration(out.elapsed)
    );
    // sum(1..=8) = 36; each round adds 8; 100-36 = 64 -> 8 rounds.
    assert_eq!(vals, (9..=16).collect::<Vec<i64>>());
    Ok(())
}
