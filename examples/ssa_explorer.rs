//! SSA explorer: watch a LabyLang program travel the compiler pipeline —
//! imperative IR, CFG structure, SSA with Φs (the paper's Fig. 3a), and
//! the final dataflow with condition nodes and conditional edges
//! (Fig. 3b), plus Graphviz DOT output.
//!
//!   cargo run --release --example ssa_explorer -- [program.laby]

use labyrinth::cfg::{dom, loops, Cfg};
use labyrinth::frontend::parse_and_lower;

const DEFAULT: &str = r#"
day = 1;
yesterday = bag();
while (day <= 365) {
    visits = source("visits").map(|x| pair(x, 1));
    counts = visits.reduceByKey(|a, b| a + b);
    if (day != 1) {
        diffs = counts.join(yesterday).map(|p| abs(fst(snd(p)) - snd(snd(p))));
        collect(diffs, "diffs");
    }
    yesterday = counts;
    day = day + 1;
}
"#;

fn main() -> labyrinth::Result<()> {
    let src = match std::env::args().nth(1) {
        Some(path) => std::fs::read_to_string(path)?,
        None => DEFAULT.to_string(),
    };

    let program = parse_and_lower(&src)?;
    println!("==== 1. imperative three-address IR ====\n{}", program.listing());

    let cfg = Cfg::from_program(&program)?;
    let dt = dom::dominators(&cfg);
    let li = loops::find_loops(&cfg, &dt);
    println!("==== 2. control-flow structure ====");
    for &b in &cfg.rpo {
        println!(
            "bb{b}: succs={:?} preds={:?} loop-depth={} chain={:?}",
            cfg.succs[b], cfg.preds[b], li.depth[b], cfg.chain(b)
        );
    }
    for l in &li.loops {
        println!("natural loop: header=bb{} latch=bb{} body={:?}", l.header, l.latch, l.body);
    }

    let ssa = labyrinth::ssa::construct(&cfg)?;
    println!("\n==== 3. SSA (paper Fig. 3a) ====\n{}", ssa.listing());

    let (graph, explain) =
        labyrinth::compile_with(&program, &labyrinth::opt::OptConfig::default())?;
    println!("==== 4. dataflow (paper Fig. 3b, after opt:: passes) ====");
    println!(
        "{} nodes, {} condition node(s), entry chain {:?}",
        graph.num_nodes(),
        graph.condition_nodes().len(),
        graph.entry_chain
    );
    for n in &graph.nodes {
        let conds: Vec<&str> = n
            .inputs
            .iter()
            .map(|i| if i.conditional { "cond" } else { "same-block" })
            .collect();
        println!(
            "  {} [{}] bb{} par={:?} inputs={:?}{}{}",
            n.name,
            n.op.mnemonic(),
            n.block,
            n.par,
            conds,
            if n.cond.is_some() { "  <- CONDITION NODE" } else { "" },
            match n.hoisted_from {
                Some(b) => format!("  <- HOISTED from bb{b}"),
                None => String::new(),
            }
        );
    }

    println!("\n==== 5. optimizer explain ====");
    print!("{}", explain.render());

    println!("\n==== 6. graphviz (pipe to `dot -Tsvg`; hoisted preambles are \
              clustered, fused chains green) ====");
    print!("{}", labyrinth::dataflow::dot::to_dot(&graph));
    Ok(())
}
