//! End-to-end driver (EXPERIMENTS.md §E2E): the paper's Visit Count
//! program (§3.1, Listing 2b) on a real generated dataset, through the
//! full stack — LabyLang source → CFG → SSA → single cyclic dataflow →
//! multi-worker engine with file I/O — validated against the
//! single-threaded oracle and compared with the separate-jobs baselines.
//!
//!   cargo run --release --example visit_count -- [days] [visits_per_day] [workers]

use labyrinth::baselines::{separate_jobs, single_thread};
use labyrinth::exec::{ExecConfig, ExecMode};
use labyrinth::util::fmt_duration;
use labyrinth::workload::VisitCountWorkload;

const PROGRAM: &str = r#"
pageAttributes = readFile("pageAttributes")
    .map(|l| pair(int(field(l, 0)), int(field(l, 1))));
day = 1;
yesterdayCounts = bag();
while (day <= DAYS) {
    visits = readFile("pageVisitLog" + str(day)).map(|l| pair(int(l), 1));
    joined = visits.join(pageAttributes).filter(|p| fst(snd(p)) == 0);
    counts = joined.map(|p| pair(fst(p), 1)).reduceByKey(|a, b| a + b);
    if (day != 1) {
        diffs = counts.join(yesterdayCounts)
            .map(|p| abs(fst(snd(p)) - snd(snd(p))));
        total = diffs.reduce(|a, b| a + b);
        collect(bag(0).map(|z| z + total), "daily_diffs");
    }
    yesterdayCounts = counts;
    day = day + 1;
}
"#;

fn main() -> labyrinth::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let days: usize = args.first().map(|s| s.parse().unwrap()).unwrap_or(10);
    let visits: usize = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(20_000);
    let workers: usize = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(4);

    // 1. Generate the dataset on disk (real files; readFile is exercised).
    let dir = std::env::temp_dir().join("laby_visit_count_e2e");
    let w = VisitCountWorkload {
        days,
        visits_per_day: visits,
        num_pages: 2_000,
        ..Default::default()
    };
    w.write_files(&dir)?;
    println!(
        "dataset: {days} days × {visits} visits over {} pages at {}",
        w.num_pages,
        dir.display()
    );

    let src = PROGRAM.replace("DAYS", &days.to_string());
    let program = labyrinth::frontend::parse_and_lower(&src)?;

    // 2. Oracle: single-threaded COST-style interpreter.
    let st_cfg = single_thread::SingleThreadConfig {
        io_dir: dir.clone(),
        ..Default::default()
    };
    let t = std::time::Instant::now();
    let oracle = single_thread::run(&program, &st_cfg)?;
    let t_single = t.elapsed();
    let mut want: Vec<i64> = oracle.collected("daily_diffs").iter().map(|v| v.as_i64()).collect();

    // 3. Labyrinth: one cyclic dataflow job, pipelined.
    let graph = labyrinth::compile(&program)?;
    let lab_cfg = ExecConfig {
        workers,
        io_dir: dir.clone(),
        sched: Some(labyrinth::sched::LatencyModel::flink_like()),
        ..Default::default()
    };
    let lab = labyrinth::exec::run(&graph, &lab_cfg)?;
    let mut got: Vec<i64> = lab.collected("daily_diffs").iter().map(|v| v.as_i64()).collect();
    want.sort();
    got.sort();
    assert_eq!(got, want, "Labyrinth output must match the oracle");

    // 3b. Barrier mode (pipelining ablation, §9.3).
    let barrier = labyrinth::exec::run(
        &graph,
        &ExecConfig { mode: ExecMode::Barrier, ..lab_cfg.clone() },
    )?;

    // 4. Baselines: one dataflow job per step.
    let mut spark_cfg = separate_jobs::SeparateJobsConfig::spark(workers);
    spark_cfg.io_dir = dir.clone();
    let spark = separate_jobs::run(&program, &spark_cfg)?;
    let mut spark_got: Vec<i64> =
        spark.collected("daily_diffs").iter().map(|v| v.as_i64()).collect();
    spark_got.sort();
    assert_eq!(spark_got, want, "Spark-like output must match the oracle");

    let mut flink_cfg = separate_jobs::SeparateJobsConfig::flink(workers);
    flink_cfg.io_dir = dir.clone();
    let flink = separate_jobs::run(&program, &flink_cfg)?;

    // 5. Report (the paper's headline: in-dataflow control flow removes
    //    per-step scheduling; reuse + pipelining compound).
    let n_inputs = days * visits;
    println!("\n== Visit Count end-to-end ({workers} workers) ==");
    println!(
        "{:<28} {:>12}  {:>14}  note",
        "executor", "wall", "sched overhead"
    );
    println!(
        "{:<28} {:>12}  {:>14}  1 job, pipelined steps",
        "labyrinth (pipelined)",
        fmt_duration(lab.elapsed),
        fmt_duration(lab.sched_overhead)
    );
    println!(
        "{:<28} {:>12}  {:>14}  1 job, per-step barriers",
        "labyrinth (barrier)",
        fmt_duration(barrier.elapsed),
        fmt_duration(barrier.sched_overhead)
    );
    println!(
        "{:<28} {:>12}  {:>14}  {} jobs",
        "spark-like separate jobs",
        fmt_duration(spark.elapsed),
        fmt_duration(spark.sched_time),
        spark.jobs_launched
    );
    println!(
        "{:<28} {:>12}  {:>14}  {} jobs + collect-to-driver",
        "flink-like separate jobs",
        fmt_duration(flink.elapsed),
        fmt_duration(flink.sched_time),
        flink.jobs_launched
    );
    println!(
        "{:<28} {:>12}  {:>14}  McSherry COST baseline",
        "single-threaded",
        fmt_duration(t_single),
        "-"
    );
    println!(
        "\nthroughput (labyrinth): {:.1}k visits/s over {} total visits",
        n_inputs as f64 / lab.elapsed.as_secs_f64() / 1e3,
        n_inputs
    );
    println!(
        "state reuse: {} build-side reuses, {} rebuilds",
        lab.metrics.get("coord.state_reused"),
        lab.metrics.get("coord.state_dropped")
    );
    println!("daily diffs (first 5): {:?}", &got[..got.len().min(5)]);
    Ok(())
}
