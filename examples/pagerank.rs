//! PageRank two ways (§9.2.2 / Fig. 7 workload):
//!
//! 1. **Accelerated**: the rank update is the AOT-compiled Pallas kernel
//!    (`artifacts/pagerank_step.hlo.txt`), driven from inside a Labyrinth
//!    loop. The loop-invariant edge bag is tensorized once and cached on
//!    the XLA service (§7 state reuse on a tensor operator).
//! 2. **Pure dataflow**: the same fixpoint as join/reduceByKey operators —
//!    the shape Flink/Spark programs use; validated against the reference.
//!
//!   make artifacts && cargo run --release --example pagerank -- [n] [iters] [workers]

use labyrinth::prelude::*;
use labyrinth::runtime::XlaCallSpec;
use labyrinth::util::fmt_duration;
use labyrinth::workload::pagerank_reference;

fn build_graph(n: usize) -> Vec<(usize, usize)> {
    // Ring + skip links + a few hubs: strongly connected, no danglings.
    let mut edges = Vec::new();
    for i in 0..n {
        edges.push((i, (i + 1) % n));
        edges.push((i, (i * 7 + 3) % n));
        if i % 11 == 0 {
            edges.push((i, 0));
        }
    }
    edges
}

fn main() -> labyrinth::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map(|s| s.parse().unwrap()).unwrap_or(512);
    let iters: i64 = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(20);
    let workers: usize = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(4);

    let edges = build_graph(n);
    let reference = pagerank_reference(&edges, n, iters as usize);
    let edge_vals: Vec<Value> = edges
        .iter()
        .map(|&(s, d)| Value::pair(Value::I64(s as i64), Value::I64(d as i64)))
        .collect();
    labyrinth::workload::registry::global().put("pr_edges", edge_vals);
    let init: Vec<Value> = (0..n)
        .map(|p| Value::pair(Value::I64(p as i64), Value::F64(1.0 / n as f64)))
        .collect();

    // ---- variant 1: accelerated (XLA artifact inside the loop) ----------
    let accelerated = labyrinth::runtime::XlaService::global().available("pagerank_step")
        && n == 512; // artifact is compiled for the static shape n=512
    let mut results = Vec::new();
    if accelerated {
        let mut b = ProgramBuilder::new();
        let edges_bag = b.named_source("pr_edges");
        let r0 = b.bag_lit(init.clone());
        let ranks = b.declare_bag("ranks", r0);
        let zero = b.scalar_i64(0);
        let i = b.declare_scalar("i", zero);
        b.while_(
            |b| b.scalar_lt_i64(i, iters),
            |b| {
                let next = b.xla_call(vec![edges_bag, ranks], XlaCallSpec::pagerank_step(n));
                b.assign_bag(ranks, next);
                let i2 = b.scalar_add_i64(i, 1);
                b.assign_scalar(i, i2);
            },
        );
        b.collect(ranks, "ranks");
        let graph = labyrinth::compile(&b.finish())?;
        let t = std::time::Instant::now();
        let out = run(&graph, &ExecConfig { workers, ..Default::default() })?;
        let wall = t.elapsed();
        check(&out.collected("ranks"), &reference, 1e-3, "accelerated");
        results.push(("labyrinth + pallas artifact", wall));
    } else {
        println!("(skipping accelerated variant: run `make artifacts` and use n=512)");
    }

    // ---- variant 2: pure dataflow fixpoint -------------------------------
    // contribs = ranks join out-degree'd edges -> per-target shares;
    // next = reduceByKey(+) with teleport. Expressed via the builder.
    let mut outdeg = vec![0i64; n];
    for &(s, _) in &edges {
        outdeg[s] += 1;
    }
    let adj: Vec<Value> = edges
        .iter()
        .map(|&(s, d)| {
            Value::pair(
                Value::I64(s as i64),
                Value::pair(Value::I64(d as i64), Value::F64(1.0 / outdeg[s] as f64)),
            )
        })
        .collect();
    labyrinth::workload::registry::global().put("pr_adj", adj);
    let damping = 0.85;
    let teleport = (1.0 - damping) / n as f64;

    let mut b = ProgramBuilder::new();
    let adj_bag = b.named_source("pr_adj");
    let r0 = b.bag_lit(init);
    let ranks = b.declare_bag("ranks", r0);
    let zero = b.scalar_i64(0);
    let i = b.declare_scalar("i", zero);
    b.while_(
        |b| b.scalar_lt_i64(i, iters),
        |b| {
            // join adjacency (build, invariant) with ranks (probe) on page.
            let joined = b.join(adj_bag, ranks);
            // (page, ((dst, w), rank)) -> (dst, damping * rank * w)
            let contribs = b.map(
                joined,
                udf1(move |v| {
                    let kv = v.val(); // ((dst, w), rank)
                    let dst_w = kv.key();
                    let rank = kv.val().as_f64();
                    Value::pair(
                        dst_w.key().clone(),
                        Value::F64(damping * rank * dst_w.val().as_f64()),
                    )
                }),
            );
            let summed = b.reduce_by_key(
                contribs,
                udf2(|a, c| Value::F64(a.as_f64() + c.as_f64())),
            );
            // add teleport everywhere (pages always have in-links here).
            let next = b.map(
                summed,
                udf1(move |v| {
                    Value::pair(v.key().clone(), Value::F64(v.val().as_f64() + teleport))
                }),
            );
            b.assign_bag(ranks, next);
            let i2 = b.scalar_add_i64(i, 1);
            b.assign_scalar(i, i2);
        },
    );
    b.collect(ranks, "ranks");
    let graph = labyrinth::compile(&b.finish())?;
    let t = std::time::Instant::now();
    let out = run(&graph, &ExecConfig { workers, ..Default::default() })?;
    let wall = t.elapsed();
    check(&out.collected("ranks"), &reference, 1e-6, "pure dataflow");
    println!(
        "join build-side reuses across steps: {}",
        out.metrics.get("coord.state_reused")
    );
    results.push(("labyrinth pure dataflow", wall));

    println!("\n== PageRank n={n}, {iters} iterations, {workers} workers ==");
    for (name, wall) in results {
        println!("{name:<28} {}", fmt_duration(wall));
    }
    Ok(())
}

fn check(got_bag: &[Value], want: &[f64], tol: f64, label: &str) {
    let n = want.len();
    assert_eq!(got_bag.len(), n, "{label}: rank count");
    let mut got = vec![0.0; n];
    for v in got_bag {
        got[v.key().as_i64() as usize] = v.val().as_f64();
    }
    let max_err = got
        .iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f64, f64::max);
    assert!(max_err < tol, "{label}: max rank error {max_err} > {tol}");
    println!("{label}: matches reference (max err {max_err:.2e})");
}
