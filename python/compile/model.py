"""L2: the JAX compute graphs exported as AOT artifacts. Each model wraps
an L1 Pallas kernel (so the kernel lowers into the same HLO module) plus
any surrounding jnp glue; `aot.py` lowers these once to HLO text and the
Rust runtime executes them forever after.

Build-time only — never imported on the request path.
"""

import jax.numpy as jnp

from . import shapes
from .kernels import histogram as histogram_kernel
from .kernels import incr as incr_kernel
from .kernels import pagerank as pagerank_kernel


def pagerank_step_model(m, r):
    """One damped PageRank step with L1-normalization guard.

    The normalization keeps the rank vector a distribution even under f32
    accumulation drift across hundreds of steps (the Rust inner loop can
    run the artifact repeatedly without host-side renormalization).
    """
    nxt = pagerank_kernel.pagerank_step(
        m,
        r,
        damping=shapes.PAGERANK_DAMPING,
        block_rows=shapes.PAGERANK_BLOCK_ROWS,
    )
    return (nxt / jnp.sum(nxt),)


def histogram_model(ids):
    """Dense visit-count histogram over int32 page ids."""
    return (
        histogram_kernel.histogram(
            ids, bins=shapes.HIST_BINS, chunk=shapes.HIST_CHUNK
        ),
    )


def incr_model(x):
    """Elementwise x + 1 (Fig. 5 microbench map)."""
    return (incr_kernel.incr(x),)
