"""Static artifact shapes shared by the L1 kernels, the L2 models, the AOT
exporter, and (by convention — see DESIGN.md §7) the Rust bridge.

PJRT executables are compiled for fixed shapes; the Rust side pads bags to
these capacities and truncates outputs.
"""

# PageRank: dense damped power-iteration step over an n x n transition matrix.
PAGERANK_N = 512
PAGERANK_BLOCK_ROWS = 128  # VMEM tile height for the Pallas kernel
PAGERANK_DAMPING = 0.85

# Visit-count histogram: count int32 page ids into dense bins.
HIST_CAPACITY = 4096  # ids per artifact invocation (Rust chunks larger bags)
HIST_BINS = 2048
HIST_CHUNK = 512  # ids per Pallas grid step (one-hot tile height)

# Elementwise increment (Fig. 5 microbench map as an artifact).
INCR_CAPACITY = 256
