"""L1 Pallas kernel: elementwise increment — the Fig. 5 microbench map
(`bag.map(x => x + 1)`) as an AOT artifact, so the iteration-step-overhead
experiment can also run its per-step compute through the PJRT path."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1.0


def incr(x, *, block=128, interpret=True):
    """x + 1 over a 1-D f32 vector, tiled into VPU-friendly blocks."""
    n = x.shape[0]
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(x)
