"""L1 Pallas kernel: dense integer histogram (the Visit Count hot spot).

TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of scalar
scatter-adds (a GPU-atomics idiom), counting is expressed as a one-hot
comparison tile contracted against ones — an MXU-friendly matmul shape.
The id stream is tiled with ``BlockSpec`` into ``(chunk,)`` slices; each
grid step materializes a ``(chunk, bins)`` one-hot tile in VMEM and
accumulates into the single ``(bins,)`` output block (all grid steps map
to output block 0, the standard Pallas reduction pattern).

Out-of-range ids — including the ``-1`` padding the Rust bridge uses —
match no bin and are counted nowhere.

VMEM per grid step (f32): chunk * bins = 512 * 2048 ~= 4 MiB (defaults).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, o_ref, *, bins, chunk):
    step = pl.program_id(0)
    ids = ids_ref[...]
    one_hot = (
        ids[:, None] == jax.lax.broadcasted_iota(jnp.int32, (chunk, bins), 1)
    ).astype(jnp.float32)
    # ones(1, chunk) @ one_hot(chunk, bins): counting on the MXU.
    tile_counts = jnp.dot(
        jnp.ones((chunk,), jnp.float32), one_hot, preferred_element_type=jnp.float32
    )

    @pl.when(step == 0)
    def _init():
        o_ref[...] = tile_counts

    @pl.when(step != 0)
    def _acc():
        o_ref[...] += tile_counts


def histogram(ids, *, bins, chunk=512, interpret=True):
    """Count ids in [0, bins) into dense f32 bins."""
    capacity = ids.shape[0]
    if capacity % chunk != 0:
        raise ValueError(f"capacity={capacity} must be a multiple of chunk={chunk}")
    return pl.pallas_call(
        functools.partial(_kernel, bins=bins, chunk=chunk),
        grid=(capacity // chunk,),
        in_specs=[pl.BlockSpec((chunk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((bins,), jnp.float32),
        interpret=interpret,
    )(ids)
