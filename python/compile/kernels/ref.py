"""Pure-jnp oracles for the Pallas kernels — the correctness specification
every kernel is tested against (pytest + hypothesis sweeps)."""

import jax.numpy as jnp


def pagerank_step_ref(m, r, damping=0.85):
    """One damped power-iteration step: damping * M @ r + (1-d)/n.

    ``m`` is the column-stochastic transition matrix (dangling columns
    already uniform — the Rust bridge builds it that way).
    """
    n = r.shape[0]
    return damping * (m @ r) + (1.0 - damping) / n


def histogram_ref(ids, bins):
    """Count int32 ids into ``bins`` dense f32 bins; out-of-range ids
    (including the -1 padding the Rust bridge uses) are ignored."""
    valid = (ids >= 0) & (ids < bins)
    return jnp.where(
        jnp.arange(bins)[None, :] == jnp.where(valid, ids, -1)[:, None], 1.0, 0.0
    ).sum(axis=0)


def incr_ref(x):
    """Elementwise x + 1 (the paper's Fig. 5 microbench map UDF)."""
    return x + 1.0
