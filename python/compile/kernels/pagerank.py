"""L1 Pallas kernel: one damped PageRank power-iteration step.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the step is tiled with
``BlockSpec`` so each grid step holds one ``(block_rows, n)`` slab of the
transition matrix in VMEM and produces a ``block_rows`` rank tile via an
MXU matvec. Under ``interpret=True`` this executes as plain HLO, which is
what the CPU PJRT plugin (and therefore the Rust runtime) runs; on a real
TPU the same BlockSpec schedule drives the HBM->VMEM pipeline.

VMEM footprint per grid step (f32):
    block_rows * n + n + block_rows  floats
    = 128 * 512 + 512 + 128  ~= 0.26 MiB   (default shapes)
comfortably double-bufferable within the ~16 MiB VMEM budget; see
EXPERIMENTS.md §Perf for the block-size sweep.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(m_ref, r_ref, o_ref, *, damping, n):
    # One (block_rows, n) slab of M against the full rank vector: an MXU
    # matvec accumulated in f32, plus the uniform teleport term.
    o_ref[...] = damping * jnp.dot(
        m_ref[...], r_ref[...], preferred_element_type=jnp.float32
    ) + (1.0 - damping) / n


def pagerank_step(m, r, *, damping=0.85, block_rows=128, interpret=True):
    """rank' = damping * M @ rank + (1 - damping) / n, tiled over rows."""
    n = r.shape[0]
    if n % block_rows != 0:
        raise ValueError(f"n={n} must be a multiple of block_rows={block_rows}")
    return pl.pallas_call(
        functools.partial(_kernel, damping=damping, n=n),
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(m, r)
