"""AOT exporter: lower the L2 models (wrapping L1 Pallas kernels) to HLO
**text** artifacts the Rust runtime loads via `HloModuleProto::from_text_file`.

Text — not `.serialize()` — is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the pinned xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts        # all artifacts
    python -m compile.aot --only histogram --out-dir ...
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_specs():
    """name -> (fn, example argument shapes)."""
    n = shapes.PAGERANK_N
    return {
        "pagerank_step": (
            model.pagerank_step_model,
            (
                jax.ShapeDtypeStruct((n, n), jnp.float32),
                jax.ShapeDtypeStruct((n,), jnp.float32),
            ),
        ),
        "histogram": (
            model.histogram_model,
            (jax.ShapeDtypeStruct((shapes.HIST_CAPACITY,), jnp.int32),),
        ),
        "incr": (
            model.incr_model,
            (jax.ShapeDtypeStruct((shapes.INCR_CAPACITY,), jnp.float32),),
        ),
    }


def export(name, fn, args, out_dir):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"aot: wrote {path} ({len(text)} chars)")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", action="append", help="export only these artifacts")
    ap.add_argument("--list", action="store_true", help="list artifact names")
    args = ap.parse_args(argv)

    specs = artifact_specs()
    if args.list:
        print("\n".join(specs))
        return 0
    names = args.only or list(specs)
    os.makedirs(args.out_dir, exist_ok=True)
    for name in names:
        if name not in specs:
            print(f"aot: unknown artifact {name!r} (have: {', '.join(specs)})")
            return 1
        fn, shapes_ = specs[name]
        export(name, fn, shapes_, args.out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
