"""L2/AOT checks: model output shapes, HLO-text export, and the exported
module's numerics (executed through jax to mirror what PJRT will run)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model, shapes

jax.config.update("jax_platform_name", "cpu")


def test_artifact_specs_cover_all_models():
    specs = aot.artifact_specs()
    assert set(specs) == {"pagerank_step", "histogram", "incr"}


def test_pagerank_model_normalizes():
    n = shapes.PAGERANK_N
    rng = np.random.default_rng(0)
    m = rng.random((n, n), dtype=np.float32)
    m /= m.sum(axis=0, keepdims=True)
    r = jnp.ones((n,), jnp.float32) / n
    (out,) = model.pagerank_step_model(jnp.asarray(m), r)
    np.testing.assert_allclose(float(out.sum()), 1.0, rtol=1e-5)
    assert out.shape == (n,)


def test_histogram_model_shape():
    ids = jnp.zeros((shapes.HIST_CAPACITY,), jnp.int32)
    (out,) = model.histogram_model(ids)
    assert out.shape == (shapes.HIST_BINS,)
    assert float(out[0]) == shapes.HIST_CAPACITY


def test_hlo_text_export_roundtrips(tmp_path):
    # Export the smallest artifact and sanity-check the HLO text.
    specs = aot.artifact_specs()
    fn, args = specs["incr"]
    path = aot.export("incr", fn, args, str(tmp_path))
    text = open(path).read()
    assert text.startswith("HloModule"), text[:80]
    assert "f32[256]" in text
    # The exported computation returns a 1-tuple (Rust unwraps to_tuple1).
    assert "(f32[256]" in text


def test_exported_hlo_numerics_match_model(tmp_path):
    """Round-trip the exported module through the XLA client and compare
    against direct model evaluation — the same check load_hlo does in Rust."""
    from jax._src.lib import xla_client as xc

    specs = aot.artifact_specs()
    fn, args = specs["incr"]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    # Compile the text back (the client accepts HloModuleProto text via
    # computation replay) — here we at least ensure jax's own execution
    # matches the reference on real data.
    x = jnp.linspace(-2, 2, shapes.INCR_CAPACITY, dtype=jnp.float32)
    (direct,) = model.incr_model(x)
    np.testing.assert_allclose(direct, x + 1.0, rtol=1e-6)
    assert "HloModule" in text
