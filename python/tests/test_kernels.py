"""L1 correctness: Pallas kernels vs pure-jnp oracles, swept over shapes
and values with hypothesis. This is the CORE numeric correctness signal —
the Rust runtime executes exactly what these kernels lower to."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import shapes
from compile.kernels import histogram as hk
from compile.kernels import incr as ik
from compile.kernels import pagerank as pk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


# ---- pagerank ------------------------------------------------------------


def random_stochastic(n, seed):
    rng = np.random.default_rng(seed)
    m = rng.random((n, n), dtype=np.float32)
    m /= m.sum(axis=0, keepdims=True)
    return jnp.asarray(m)


@pytest.mark.parametrize("n,block", [(64, 16), (128, 128), (256, 64), (512, 128)])
def test_pagerank_matches_ref_across_tilings(n, block):
    m = random_stochastic(n, seed=n)
    r = jnp.ones((n,), jnp.float32) / n
    got = pk.pagerank_step(m, r, block_rows=block)
    want = ref.pagerank_step_ref(m, r)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    damping=st.floats(0.0, 1.0, allow_nan=False),
)
def test_pagerank_hypothesis_damping_sweep(seed, damping):
    n, block = 64, 32
    m = random_stochastic(n, seed)
    rng = np.random.default_rng(seed + 1)
    r = jnp.asarray(rng.random(n, dtype=np.float32))
    got = pk.pagerank_step(m, r, damping=damping, block_rows=block)
    want = ref.pagerank_step_ref(m, r, damping=damping)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_pagerank_preserves_distribution_mass():
    n = 128
    m = random_stochastic(n, seed=3)
    r = jnp.ones((n,), jnp.float32) / n
    out = pk.pagerank_step(m, r, block_rows=32)
    np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-5)


def test_pagerank_rejects_bad_tiling():
    m = jnp.zeros((60, 60), jnp.float32)
    r = jnp.zeros((60,), jnp.float32)
    with pytest.raises(ValueError):
        pk.pagerank_step(m, r, block_rows=32)


# ---- histogram -------------------------------------------------------------


@pytest.mark.parametrize("capacity,bins,chunk", [(64, 16, 16), (256, 64, 64), (512, 128, 128)])
def test_histogram_matches_ref(capacity, bins, chunk):
    rng = np.random.default_rng(capacity)
    ids = jnp.asarray(rng.integers(-1, bins, capacity, dtype=np.int32))
    got = hk.histogram(ids, bins=bins, chunk=chunk)
    want = ref.histogram_ref(ids, bins)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_histogram_hypothesis_values(data):
    bins, chunk, capacity = 32, 16, 64
    ids = data.draw(
        st.lists(
            st.integers(-5, bins + 5), min_size=capacity, max_size=capacity
        )
    )
    ids = jnp.asarray(np.array(ids, dtype=np.int32))
    got = np.asarray(hk.histogram(ids, bins=bins, chunk=chunk))
    want = np.asarray(ref.histogram_ref(ids, bins))
    np.testing.assert_array_equal(got, want)
    # Total mass == number of in-range ids.
    in_range = int(((ids >= 0) & (ids < bins)).sum())
    assert got.sum() == in_range


def test_histogram_all_padding_is_zero():
    ids = jnp.full((64,), -1, jnp.int32)
    got = hk.histogram(ids, bins=16, chunk=16)
    assert float(got.sum()) == 0.0


# ---- incr ------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_incr_matches_ref(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256, dtype=np.float32))
    got = ik.incr(x)
    np.testing.assert_allclose(got, ref.incr_ref(x), rtol=1e-6)


@pytest.mark.parametrize("n,block", [(128, 128), (256, 64), (512, 128)])
def test_incr_tilings(n, block):
    x = jnp.arange(n, dtype=jnp.float32)
    got = ik.incr(x, block=block)
    np.testing.assert_allclose(got, x + 1.0)
